//! Core-affinity shim — the first slice of the NUMA roadmap item.
//!
//! Gated behind the `affinity` cargo feature (default **off**): the
//! default build carries no platform dependency and compiles the no-op
//! stub below, so call sites stay unconditional. With the feature on
//! (Linux only), [`pin_current`] pins the calling thread via
//! `sched_getaffinity`/`sched_setaffinity`, declared directly against
//! libc — glibc and musl both export the symbols, so no crate
//! dependency is needed (the offline build image vendors none).
//!
//! Core indices are **logical**: `pin_current(i)` pins to the i-th CPU
//! of the thread's *currently allowed* set (mod its size), not to
//! absolute CPU ids — under a container/cgroup mask like `cpus 2-3`,
//! index 0 means CPU 2. Pinning therefore works (and the feature's CI
//! smoke passes) on restricted and non-contiguous masks.
//!
//! Pinning policy (documented, deliberately simple):
//!
//! * each [`crate::engine::EngineRunner`] pool thread pins to
//!   `core_base + t` (its thread index offset by the runner's core
//!   base) — on the single-worker scaling benches this maps engine
//!   chunks 1:1 onto allowed cores;
//! * multi-worker in-process runs stripe workers across cores via
//!   `cluster.core_offset`: worker `w` passes `w * core_offset` as the
//!   base, so with `core_offset = engine_threads` workers own disjoint
//!   core ranges instead of colliding on `0..T`. The default offset 0
//!   keeps the historical shared layout;
//! * the switch thread ([`crate::switch::runner::spawn`]) pins to the
//!   **last** allowed core ([`last_core`]), keeping the fan-in point
//!   off the engine cores.
//!
//! NUMA-local shard placement (the second roadmap slice) rides on the
//! pinning: once an engine-pool thread is pinned, it first-touches its
//! model/gradient scratch and `mbind`s its engines' bit-planes onto its
//! own node ([`bind_to_current_node`]) so steady-state plane streaming
//! reads local memory. Like pinning, this needs no crate dependency —
//! `mbind` and `getcpu` have no glibc wrappers, so they go through a
//! direct `syscall(2)` declaration (x86_64 and aarch64 numbers only;
//! other architectures get the stub). Placement is best-effort and
//! advisory: single-node hosts short-circuit ([`numa_nodes`]), a kernel
//! refusing `mbind` changes nothing, and `cluster.numa_local = false`
//! opts out — values never change, only which node backs the pages.

/// Logical index of the last available core — the switch thread's home
/// (see the module docs; [`pin_current`] maps it into the allowed set).
pub fn last_core() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) - 1
}

/// Pin the calling thread to logical core `core` — the core-th CPU of
/// the thread's allowed set, taken mod the set size. Returns `true` on
/// success; always `false` when the `affinity` feature is off or the
/// platform is unsupported.
#[cfg(all(feature = "affinity", target_os = "linux"))]
pub fn pin_current(core: usize) -> bool {
    // One u64 word per 64 CPUs; 1024 CPUs matches glibc's cpu_set_t.
    const WORDS: usize = 1024 / 64;
    #[repr(C)]
    struct CpuSet {
        bits: [u64; WORDS],
    }
    extern "C" {
        // pid 0 = the calling thread.
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut CpuSet) -> i32;
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let size = std::mem::size_of::<CpuSet>();
    let mut allowed = CpuSet { bits: [0; WORDS] };
    // SAFETY: `allowed` is a properly sized, writable mask; the kernel
    // fills at most `size` bytes.
    if unsafe { sched_getaffinity(0, size, &mut allowed) } != 0 {
        return false;
    }
    let total: usize = allowed.bits.iter().map(|w| w.count_ones() as usize).sum();
    if total == 0 {
        return false;
    }
    // Walk to the (core % total)-th set bit of the allowed mask.
    let mut remaining = core % total;
    for (wi, &word) in allowed.bits.iter().enumerate() {
        let mut w = word;
        while w != 0 {
            let bit = w.trailing_zeros() as usize;
            if remaining == 0 {
                let mut set = CpuSet { bits: [0; WORDS] };
                set.bits[wi] |= 1u64 << bit;
                // SAFETY: `set` is a properly sized, initialized mask;
                // the kernel only reads `size` bytes from it.
                return unsafe { sched_setaffinity(0, size, &set) == 0 };
            }
            remaining -= 1;
            w &= w - 1; // clear lowest set bit
        }
    }
    false
}

/// No-op stub: the `affinity` feature is off (or the target is not
/// Linux), so threads stay wherever the scheduler puts them.
#[cfg(not(all(feature = "affinity", target_os = "linux")))]
pub fn pin_current(_core: usize) -> bool {
    false
}

/// The two NUMA syscalls glibc wraps for neither glibc nor musl
/// (`mbind` lives in libnuma, `getcpu` in the vDSO), reached through a
/// direct `syscall(2)` declaration — same no-crate-dependency rule as
/// the pinning above, which is why the numbers are per-architecture.
#[cfg(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod numa_sys {
    pub type Long = std::ffi::c_long;
    extern "C" {
        pub fn syscall(num: Long, ...) -> Long;
        pub fn sysconf(name: i32) -> Long;
    }
    /// `_SC_PAGESIZE` — 30 on both glibc and musl.
    pub const SC_PAGESIZE: i32 = 30;
    #[cfg(target_arch = "x86_64")]
    pub const SYS_GETCPU: Long = 309;
    #[cfg(target_arch = "aarch64")]
    pub const SYS_GETCPU: Long = 168;
    #[cfg(target_arch = "x86_64")]
    pub const SYS_MBIND: Long = 237;
    #[cfg(target_arch = "aarch64")]
    pub const SYS_MBIND: Long = 235;
}

/// Number of possible NUMA nodes
/// (`/sys/devices/system/node/possible`); 1 when detection fails or
/// the stub is active. Placement short-circuits on 1-node hosts.
#[cfg(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
pub fn numa_nodes() -> usize {
    std::fs::read_to_string("/sys/devices/system/node/possible")
        .ok()
        .and_then(|s| s.trim().rsplit(['-', ',']).next()?.parse::<usize>().ok())
        .map(|n| n + 1)
        .unwrap_or(1)
}

/// NUMA node the calling thread is executing on right now (`getcpu`),
/// or `None` when the syscall is unavailable. Meaningful after
/// [`pin_current`]: a pinned thread cannot migrate off its node.
#[cfg(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
pub fn current_node() -> Option<usize> {
    let mut cpu: u32 = 0;
    let mut node: u32 = 0;
    // SAFETY: getcpu writes two u32s through valid pointers; the third
    // (tcache) argument is ignored since Linux 2.6.24.
    let rc = unsafe {
        numa_sys::syscall(
            numa_sys::SYS_GETCPU,
            &mut cpu as *mut u32,
            &mut node as *mut u32,
            std::ptr::null_mut::<u8>(),
        )
    };
    (rc == 0).then_some(node as usize)
}

/// Best-effort: bind — and migrate, `MPOL_MF_MOVE` — the pages backing
/// `buf` onto the calling thread's current node via
/// `mbind(MPOL_PREFERRED)`. Page-granular by nature: neighbouring heap
/// objects sharing a boundary page follow along, which is fine for a
/// locality hint. Returns whether the kernel accepted the binding;
/// `false` on single-node hosts, empty buffers, or refused syscalls —
/// callers must treat placement as advisory.
#[cfg(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
pub fn bind_to_current_node<T>(buf: &[T]) -> bool {
    let bytes = std::mem::size_of_val(buf);
    if bytes == 0 || numa_nodes() <= 1 {
        return false;
    }
    let Some(node) = current_node() else { return false };
    if node >= 64 {
        return false; // one nodemask word covers any realistic host
    }
    let nodemask: u64 = 1u64 << node;
    // SAFETY: sysconf is a pure query.
    let page = unsafe { numa_sys::sysconf(numa_sys::SC_PAGESIZE) };
    let page = if page > 0 { page as usize } else { 4096 };
    let addr = buf.as_ptr() as usize;
    let start = addr & !(page - 1);
    let len = addr + bytes - start;
    const MPOL_PREFERRED: numa_sys::Long = 1;
    const MPOL_MF_MOVE: numa_sys::Long = 1 << 1;
    // SAFETY: [start, start + len) covers only pages at least partially
    // backing `buf`, which is live across the call; the nodemask
    // outlives it; maxnode 65 tells the kernel to consume exactly the
    // one u64 word (it reads maxnode - 1 bits).
    let rc = unsafe {
        numa_sys::syscall(
            numa_sys::SYS_MBIND,
            start,
            len,
            MPOL_PREFERRED,
            &nodemask as *const u64,
            65usize,
            MPOL_MF_MOVE,
        )
    };
    rc == 0
}

/// Stub: NUMA detection is off with the feature (or unsupported here).
#[cfg(not(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
pub fn numa_nodes() -> usize {
    1
}

/// Stub: no node information without the `affinity` feature.
#[cfg(not(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
pub fn current_node() -> Option<usize> {
    None
}

/// Stub: placement silently declines without the `affinity` feature.
#[cfg(not(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
pub fn bind_to_current_node<T>(_buf: &[T]) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_core_is_in_range() {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(last_core() < n);
    }

    #[cfg(not(all(feature = "affinity", target_os = "linux")))]
    #[test]
    fn stub_reports_unpinned() {
        assert!(!pin_current(0));
    }

    #[test]
    fn numa_detection_is_sane() {
        assert!(numa_nodes() >= 1);
    }

    #[cfg(not(all(
        feature = "affinity",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    )))]
    #[test]
    fn numa_stubs_decline() {
        assert_eq!(numa_nodes(), 1);
        assert_eq!(current_node(), None);
        assert!(!bind_to_current_node(&[0.0f32; 16]));
    }

    #[cfg(all(
        feature = "affinity",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    #[test]
    fn binding_is_best_effort_and_never_corrupts() {
        // getcpu must answer on any Linux this runs on.
        assert!(current_node().is_some());
        let buf = vec![1.0f32; 4096];
        // On a 1-node host this declines (false); either way the data
        // must be untouched — placement moves pages, not values.
        let _ = bind_to_current_node(&buf);
        assert!(buf.iter().all(|&v| v == 1.0));
        assert!(!bind_to_current_node::<f32>(&[]), "empty buffers decline");
    }

    #[cfg(all(feature = "affinity", target_os = "linux"))]
    #[test]
    fn pinning_succeeds_and_wraps() {
        // Logical indices map into the *allowed* set, so this holds
        // under restricted cpuset/taskset masks too.
        assert!(pin_current(0), "pinning to the first allowed core must succeed");
        assert!(pin_current(last_core()));
        // An out-of-range index wraps instead of failing.
        assert!(pin_current(usize::MAX - 1));
    }
}
