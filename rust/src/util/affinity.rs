//! Core-affinity shim — the first slice of the NUMA roadmap item.
//!
//! Gated behind the `affinity` cargo feature (default **off**): the
//! default build carries no platform dependency and compiles the no-op
//! stub below, so call sites stay unconditional. With the feature on
//! (Linux only), [`pin_current`] pins the calling thread via
//! `sched_getaffinity`/`sched_setaffinity`, declared directly against
//! libc — glibc and musl both export the symbols, so no crate
//! dependency is needed (the offline build image vendors none).
//!
//! Core indices are **logical**: `pin_current(i)` pins to the i-th CPU
//! of the thread's *currently allowed* set (mod its size), not to
//! absolute CPU ids — under a container/cgroup mask like `cpus 2-3`,
//! index 0 means CPU 2. Pinning therefore works (and the feature's CI
//! smoke passes) on restricted and non-contiguous masks.
//!
//! Pinning policy (documented, deliberately simple):
//!
//! * each [`crate::engine::EngineRunner`] pool thread pins to
//!   `core_base + t` (its thread index offset by the runner's core
//!   base) — on the single-worker scaling benches this maps engine
//!   chunks 1:1 onto allowed cores;
//! * multi-worker in-process runs stripe workers across cores via
//!   `cluster.core_offset`: worker `w` passes `w * core_offset` as the
//!   base, so with `core_offset = engine_threads` workers own disjoint
//!   core ranges instead of colliding on `0..T`. The default offset 0
//!   keeps the historical shared layout;
//! * the switch thread ([`crate::switch::runner::spawn`]) pins to the
//!   **last** allowed core ([`last_core`]), keeping the fan-in point
//!   off the engine cores.
//!
//! NUMA-local shard placement is the remaining roadmap slice.

/// Logical index of the last available core — the switch thread's home
/// (see the module docs; [`pin_current`] maps it into the allowed set).
pub fn last_core() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) - 1
}

/// Pin the calling thread to logical core `core` — the core-th CPU of
/// the thread's allowed set, taken mod the set size. Returns `true` on
/// success; always `false` when the `affinity` feature is off or the
/// platform is unsupported.
#[cfg(all(feature = "affinity", target_os = "linux"))]
pub fn pin_current(core: usize) -> bool {
    // One u64 word per 64 CPUs; 1024 CPUs matches glibc's cpu_set_t.
    const WORDS: usize = 1024 / 64;
    #[repr(C)]
    struct CpuSet {
        bits: [u64; WORDS],
    }
    extern "C" {
        // pid 0 = the calling thread.
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut CpuSet) -> i32;
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let size = std::mem::size_of::<CpuSet>();
    let mut allowed = CpuSet { bits: [0; WORDS] };
    // SAFETY: `allowed` is a properly sized, writable mask; the kernel
    // fills at most `size` bytes.
    if unsafe { sched_getaffinity(0, size, &mut allowed) } != 0 {
        return false;
    }
    let total: usize = allowed.bits.iter().map(|w| w.count_ones() as usize).sum();
    if total == 0 {
        return false;
    }
    // Walk to the (core % total)-th set bit of the allowed mask.
    let mut remaining = core % total;
    for (wi, &word) in allowed.bits.iter().enumerate() {
        let mut w = word;
        while w != 0 {
            let bit = w.trailing_zeros() as usize;
            if remaining == 0 {
                let mut set = CpuSet { bits: [0; WORDS] };
                set.bits[wi] |= 1u64 << bit;
                // SAFETY: `set` is a properly sized, initialized mask;
                // the kernel only reads `size` bytes from it.
                return unsafe { sched_setaffinity(0, size, &set) == 0 };
            }
            remaining -= 1;
            w &= w - 1; // clear lowest set bit
        }
    }
    false
}

/// No-op stub: the `affinity` feature is off (or the target is not
/// Linux), so threads stay wherever the scheduler puts them.
#[cfg(not(all(feature = "affinity", target_os = "linux")))]
pub fn pin_current(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_core_is_in_range() {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        assert!(last_core() < n);
    }

    #[cfg(not(all(feature = "affinity", target_os = "linux")))]
    #[test]
    fn stub_reports_unpinned() {
        assert!(!pin_current(0));
    }

    #[cfg(all(feature = "affinity", target_os = "linux"))]
    #[test]
    fn pinning_succeeds_and_wraps() {
        // Logical indices map into the *allowed* set, so this holds
        // under restricted cpuset/taskset masks too.
        assert!(pin_current(0), "pinning to the first allowed core must succeed");
        assert!(pin_current(last_core()));
        // An out-of-range index wraps instead of failing.
        assert!(pin_current(usize::MAX - 1));
    }
}
