//! Summary statistics: latency histograms with percentile whiskers
//! (paper Fig. 8 reports mean + p1/p99) and Welford online moments.

/// A sample collection with percentile queries. Stores raw samples;
/// sorting is deferred until a summary is requested.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated percentile, `q` in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        assert!(!self.xs.is_empty(), "percentile of empty sample set");
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&s, q)
    }

    pub fn summary(&self) -> Summary {
        assert!(!self.xs.is_empty(), "summary of empty sample set");
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            p1: percentile_sorted(&s, 1.0),
            p50: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

fn percentile_sorted(s: &[f64], q: f64) -> f64 {
    let n = s.len();
    if n == 1 {
        return s[0];
    }
    let rank = (q / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    s[lo] * (1.0 - frac) + s[hi.min(n - 1)] * frac
}

/// Full summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p1: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Welford's online mean/variance — used where sample counts are large
/// (e.g. per-packet switch occupancy) and storing raw samples would
/// bloat memory.
#[derive(Debug, Clone, Copy, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_set() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
    }

    #[test]
    fn summary_fields() {
        let mut s = Samples::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 8);
        assert!((sum.mean - 5.0).abs() < 1e-9);
        assert!((sum.std - 2.0).abs() < 1e-9);
        assert_eq!(sum.min, 2.0);
        assert_eq!(sum.max, 9.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64).collect();
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!((o.mean() - mean).abs() < 1e-9);
        assert!((o.variance() - var).abs() < 1e-6);
    }

    #[test]
    fn single_sample() {
        let mut s = Samples::new();
        s.push(3.0);
        let sum = s.summary();
        assert_eq!(sum.p1, 3.0);
        assert_eq!(sum.p99, 3.0);
    }
}
