//! Small self-contained utilities the rest of the crate builds on.
//!
//! The offline build image only vendors the `xla` crate's dependency
//! closure, so the usual ecosystem crates are re-implemented here at the
//! size we actually need: [`rng`] replaces `rand`, [`stats`] the summary
//! side of `criterion`, [`cli`] replaces `clap`, and [`prop`] is a seeded
//! randomized-case runner standing in for `proptest` (see DESIGN.md).

pub mod affinity;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;

/// Round `n` up to the next multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable duration from nanoseconds (for report tables).
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2}s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 32), 0);
        assert_eq!(round_up(1, 32), 32);
        assert_eq!(round_up(32, 32), 32);
        assert_eq!(round_up(33, 32), 64);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(17), "17ns");
        assert_eq!(fmt_ns(1_200), "1.20us");
        assert_eq!(fmt_ns(2_500_000), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.00s");
    }
}
