//! Transports: how workers, the switch, and baseline servers exchange
//! [`Packet`]s.
//!
//! Two implementations share one [`Transport`] trait:
//!
//! * [`sim::SimNet`] — an in-process fabric with configurable loss,
//!   duplication, reordering, and latency. This is the default substrate:
//!   it makes every retransmission path in Algorithms 2/3 actually
//!   execute, deterministically per seed.
//! * [`udp::UdpEndpoint`] — real localhost UDP datagrams (one socket
//!   per node, built via [`udp::build`]) for end-to-end realism; loss
//!   comes from the kernel (rare), so protocol fault paths are
//!   exercised via `SimNet`.

pub mod sim;
pub mod udp;

use crate::protocol::Packet;
use std::time::Duration;

/// Node address. Workers are `0..M`; the switch/server is `M` by
/// convention (see [`switch_node`]).
pub type NodeId = usize;

/// Conventional switch node id for an `m`-worker cluster.
pub fn switch_node(workers: usize) -> NodeId {
    workers
}

/// Conventional supervisor (membership watchdog) node id for an
/// `m`-worker cluster — one past the switch. The trainers always
/// provision it; it stays silent unless supervision is enabled.
pub fn supervisor_node(workers: usize) -> NodeId {
    workers + 1
}

/// Tree node plan: for an `m`-worker, `L`-leaf two-level tree the
/// address space is workers `0..m`, leaves `m..m+L`, the spine at
/// `m+L`, and the supervisor/coordinator at `m+L+1`. Leaf `l`'s node
/// id (it replaces the flat switch for its pod's workers).
pub fn leaf_node(workers: usize, leaf: usize) -> NodeId {
    workers + leaf
}

/// Spine node id in an `m`-worker, `leaves`-leaf tree.
pub fn spine_node(workers: usize, leaves: usize) -> NodeId {
    workers + leaves
}

/// Supervisor node id in an `m`-worker, `leaves`-leaf tree — one past
/// the spine (the flat plan's [`supervisor_node`], shifted by the
/// extra switches).
pub fn tree_supervisor_node(workers: usize, leaves: usize) -> NodeId {
    workers + leaves + 1
}

/// Serve-replica node plan: replicas sit *past* the whole training
/// address space — workers, every switch, and the supervisor — so the
/// train-and-serve topology shares one `base_port` without collisions.
/// `switches` is 1 for the flat plan and `leaves + 1` for a tree;
/// replica `r`'s node id is `workers + switches + 1 + r`.
pub fn serve_node(workers: usize, switches: usize, replica: usize) -> NodeId {
    workers + switches + 1 + replica
}

/// A bidirectional packet endpoint bound to one node.
pub trait Transport: Send {
    /// Fire-and-forget send (unreliable by design).
    fn send(&mut self, dst: NodeId, pkt: &Packet);

    /// Receive the next packet, waiting up to `timeout`.
    fn recv_timeout(&mut self, timeout: Duration) -> Option<(NodeId, Packet)>;

    /// Non-blocking receive.
    fn try_recv(&mut self) -> Option<(NodeId, Packet)> {
        self.recv_timeout(Duration::ZERO)
    }

    /// Fan one packet out to every destination in `dsts` — the
    /// multicast twin of [`Transport::send`], same fire-and-forget
    /// contract. The default loops `send`; transports with a batched
    /// tx path (see `udp`'s `sendmmsg`) override it to encode once and
    /// hand the kernel the whole fan-out in one syscall.
    fn send_many(&mut self, dsts: &[NodeId], pkt: &Packet) {
        for &dst in dsts {
            self.send(dst, pkt);
        }
    }

    /// This endpoint's node id.
    fn node(&self) -> NodeId;
}
