//! In-process simulated network fabric.
//!
//! All endpoints feed one fabric thread over an mpsc channel; the fabric
//! applies per-frame fault sampling (drop, duplicate, reorder) and a
//! latency model (fixed + exponential jitter), then forwards to the
//! destination endpoint's queue. Determinism: all randomness comes from
//! one [`Pcg32`] seeded from [`NetConfig::seed`]; with a fixed seed the
//! same frames are dropped regardless of thread timing *in the common
//! single-sender-per-step lock-step pattern* (packet arrival order at the
//! fabric is the only nondeterminism, and P4SGD's lock-step rounds keep
//! it narrow).
//!
//! Latency is modelled logically (delivery ordering via a virtual-time
//! heap) rather than by sleeping: sleeping per 500ns frame would be
//! slower *and* less precise than the OS timer. Wall-clock nanosecond
//! aggregation latencies for paper Fig. 8 come from the DES
//! ([`crate::timing`]), which shares the same protocol state machines.

use super::{NodeId, Transport};
use crate::config::NetConfig;
use crate::protocol::Packet;
use crate::util::rng::Pcg32;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// A frame in flight. Cloning a [`Packet`] (fan-out, duplication) copies
/// the header and bumps the shared payload's refcount — the fabric never
/// deep-copies activation buffers.
struct Frame {
    src: NodeId,
    dst: NodeId,
    pkt: Packet,
}

/// How an endpoint reaches its peers.
enum Path {
    /// All frames go through the fabric thread (fault/latency injection).
    Fabric(mpsc::Sender<Frame>),
    /// Fault-free, zero-latency config: deliver straight to the
    /// destination queue — one thread hop instead of two (§Perf L3).
    Direct(Vec<mpsc::Sender<(NodeId, Packet)>>),
}

/// One node's endpoint on the fabric.
pub struct SimEndpoint {
    node: NodeId,
    path: Path,
    rx: mpsc::Receiver<(NodeId, Packet)>,
}

impl Transport for SimEndpoint {
    fn send(&mut self, dst: NodeId, pkt: &Packet) {
        // Peer gone (shutdown) => packets fall on the floor, which is
        // exactly what an unreliable network is allowed to do.
        match &self.path {
            Path::Fabric(tx) => {
                let _ = tx.send(Frame { src: self.node, dst, pkt: pkt.clone() });
            }
            Path::Direct(txs) => {
                if let Some(tx) = txs.get(dst) {
                    let _ = tx.send((self.node, pkt.clone()));
                }
            }
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Option<(NodeId, Packet)> {
        if timeout.is_zero() {
            self.rx.try_recv().ok()
        } else {
            self.rx.recv_timeout(timeout).ok()
        }
    }

    fn node(&self) -> NodeId {
        self.node
    }
}

/// Counters the fabric reports at shutdown (fault-injection visibility).
#[derive(Debug, Default, Clone, Copy)]
pub struct FabricStats {
    pub frames: u64,
    pub dropped: u64,
    pub duplicated: u64,
    pub reordered: u64,
    pub straggled: u64,
}

/// Live chaos counters, shared between the fabric thread and whoever
/// built the net (the coordinators fold them into `FaultStats` at
/// attempt teardown — unlike [`FabricStats`] they are readable while
/// the fabric still runs).
#[derive(Debug, Default)]
pub struct ChaosMeter {
    /// Frames delayed because their source is the configured straggler.
    pub straggled_frames: AtomicU64,
}

/// Build a simulated network with `nodes` endpoints. The fabric thread
/// runs until every endpoint has been dropped.
pub struct SimNet;

impl SimNet {
    pub fn build(nodes: usize, cfg: &NetConfig) -> Vec<SimEndpoint> {
        Self::build_with_chaos(nodes, cfg).0
    }

    /// Like [`SimNet::build`], but also hands back the fabric's live
    /// [`ChaosMeter`] so the caller can observe straggler activity
    /// while the net is running (zeroed forever on the passthrough
    /// path — nothing to meter).
    pub fn build_with_chaos(nodes: usize, cfg: &NetConfig) -> (Vec<SimEndpoint>, Arc<ChaosMeter>) {
        let meter = Arc::new(ChaosMeter::default());
        let mut egress_txs = Vec::with_capacity(nodes);
        let mut egress_rxs = Vec::with_capacity(nodes);
        for _ in 0..nodes {
            let (tx, rx) = mpsc::channel();
            egress_txs.push(tx);
            egress_rxs.push(rx);
        }
        let passthrough = cfg.latency_ns == 0
            && cfg.jitter_ns == 0
            && cfg.drop_prob == 0.0
            && cfg.dup_prob == 0.0
            && cfg.reorder_prob == 0.0
            && !cfg.chaos.enabled();
        if passthrough {
            // No behaviour to inject: skip the fabric thread entirely.
            let eps = egress_rxs
                .into_iter()
                .enumerate()
                .map(|(node, rx)| SimEndpoint {
                    node,
                    path: Path::Direct(egress_txs.clone()),
                    rx,
                })
                .collect();
            return (eps, meter);
        }
        let (ingress_tx, ingress_rx) = mpsc::channel::<Frame>();
        let endpoints = egress_rxs
            .into_iter()
            .enumerate()
            .map(|(node, rx)| SimEndpoint { node, path: Path::Fabric(ingress_tx.clone()), rx })
            .collect();
        let cfg = cfg.clone();
        let fabric_meter = meter.clone();
        std::thread::Builder::new()
            .name("simnet-fabric".into())
            .spawn(move || fabric_loop(ingress_rx, egress_txs, cfg, fabric_meter))
            .expect("spawn fabric thread");
        (endpoints, meter)
    }
}

fn fabric_loop(
    ingress: mpsc::Receiver<Frame>,
    egress: Vec<mpsc::Sender<(NodeId, Packet)>>,
    cfg: NetConfig,
    meter: Arc<ChaosMeter>,
) -> FabricStats {
    let mut rng = Pcg32::new(cfg.seed, 0xFAB);
    let mut stats = FabricStats::default();
    // Delay-burst state: frames left in the currently active burst.
    let mut burst_left: u32 = 0;
    // (virtual deliver time ns, tiebreak counter) -> frame
    let mut heap: BinaryHeap<Reverse<(u64, u64, usize)>> = BinaryHeap::new();
    let mut stash: Vec<Option<Frame>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut counter = 0u64;
    let t0 = Instant::now();

    let push = |heap: &mut BinaryHeap<Reverse<(u64, u64, usize)>>,
                    stash: &mut Vec<Option<Frame>>,
                    free: &mut Vec<usize>,
                    counter: &mut u64,
                    at: u64,
                    frame: Frame| {
        let idx = if let Some(i) = free.pop() {
            stash[i] = Some(frame);
            i
        } else {
            stash.push(Some(frame));
            stash.len() - 1
        };
        *counter += 1;
        heap.push(Reverse((at, *counter, idx)));
    };

    loop {
        let now_ns = t0.elapsed().as_nanos() as u64;
        // Flush everything due.
        while let Some(&Reverse((at, _, idx))) = heap.peek() {
            if at > now_ns {
                break;
            }
            heap.pop();
            let frame = stash[idx].take().expect("stashed frame");
            free.push(idx);
            if let Some(tx) = egress.get(frame.dst) {
                let _ = tx.send((frame.src, frame.pkt));
            }
        }
        // Wait for the next ingress frame or the next deadline.
        let wait = match heap.peek() {
            Some(&Reverse((at, _, _))) => Duration::from_nanos(at.saturating_sub(now_ns).min(50_000)),
            // Nothing in flight: block generously for ingress.
            None => Duration::from_millis(50),
        };
        match ingress.recv_timeout(wait) {
            Ok(frame) => {
                stats.frames += 1;
                if rng.chance(cfg.drop_prob) {
                    stats.dropped += 1;
                    continue;
                }
                let now_ns = t0.elapsed().as_nanos() as u64;
                let mut lat = cfg.latency_ns;
                if cfg.jitter_ns > 0 {
                    lat += rng.exp(cfg.jitter_ns as f64) as u64;
                }
                if rng.chance(cfg.reorder_prob) {
                    // Hold the frame back past a few peers.
                    lat += 4 * (cfg.latency_ns + cfg.jitter_ns).max(1);
                    stats.reordered += 1;
                }
                // Chaos model (config-gated so a disabled model draws
                // nothing from the RNG stream — existing seeded runs
                // replay bit-identically). The straggler multiplier is
                // draw-free by design: the slow worker is *always*
                // slow, which is what the depth-D hiding bound is
                // stated against.
                if cfg.chaos.straggler == Some(frame.src) {
                    lat = (lat as f64 * cfg.chaos.straggler_factor).max(1.0) as u64;
                    stats.straggled += 1;
                    meter.straggled_frames.fetch_add(1, Ordering::Relaxed);
                }
                if cfg.chaos.burst_prob > 0.0 {
                    if burst_left > 0 {
                        burst_left -= 1;
                        lat += cfg.chaos.burst_ns;
                    } else if rng.chance(cfg.chaos.burst_prob) {
                        burst_left = cfg.chaos.burst_len.saturating_sub(1);
                        lat += cfg.chaos.burst_ns;
                    }
                }
                if rng.chance(cfg.dup_prob) {
                    stats.duplicated += 1;
                    let dup = Frame { src: frame.src, dst: frame.dst, pkt: frame.pkt.clone() };
                    push(&mut heap, &mut stash, &mut free, &mut counter, now_ns + lat + 1, dup);
                }
                push(&mut heap, &mut stash, &mut free, &mut counter, now_ns + lat, frame);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Drain remaining deliveries, then exit.
                let mut remaining: Vec<_> = heap.into_sorted_vec();
                remaining.reverse();
                for Reverse((_, _, idx)) in remaining {
                    if let Some(frame) = stash[idx].take() {
                        if let Some(tx) = egress.get(frame.dst) {
                            let _ = tx.send((frame.src, frame.pkt));
                        }
                    }
                }
                return stats;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetConfig;

    fn fast_cfg() -> NetConfig {
        NetConfig { latency_ns: 0, jitter_ns: 0, ..NetConfig::default() }
    }

    #[test]
    fn delivers_point_to_point() {
        let mut eps = SimNet::build(2, &fast_cfg());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &Packet::pa(7, 0, vec![1, 2, 3]));
        let (src, pkt) = b.recv_timeout(Duration::from_secs(1)).expect("delivery");
        assert_eq!(src, 0);
        assert_eq!(pkt.seq, 7);
        assert_eq!(pkt.payload[..], [1, 2, 3]);
    }

    #[test]
    fn preserves_order_without_faults() {
        let mut eps = SimNet::build(2, &fast_cfg());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        for i in 0..100u16 {
            a.send(1, &Packet::pa(i, 0, vec![]));
        }
        for i in 0..100u16 {
            let (_, pkt) = b.recv_timeout(Duration::from_secs(1)).expect("delivery");
            assert_eq!(pkt.seq, i);
        }
    }

    #[test]
    fn drop_all_delivers_nothing() {
        let cfg = NetConfig { drop_prob: 0.999999999, ..fast_cfg() };
        let mut eps = SimNet::build(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        for i in 0..50u16 {
            a.send(1, &Packet::pa(i, 0, vec![]));
        }
        assert!(b.recv_timeout(Duration::from_millis(100)).is_none());
    }

    #[test]
    fn duplication_delivers_extra_copies() {
        let cfg = NetConfig { dup_prob: 0.999999999, ..fast_cfg() };
        let mut eps = SimNet::build(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &Packet::pa(3, 0, vec![]));
        assert!(b.recv_timeout(Duration::from_secs(1)).is_some());
        assert!(b.recv_timeout(Duration::from_secs(1)).is_some(), "expected duplicate");
    }

    #[test]
    fn unknown_destination_is_dropped_silently() {
        let mut eps = SimNet::build(1, &fast_cfg());
        let mut a = eps.pop().unwrap();
        a.send(99, &Packet::pa(0, 0, vec![]));
        assert!(a.recv_timeout(Duration::from_millis(50)).is_none());
    }

    #[test]
    fn straggler_frames_arrive_after_fast_peers() {
        // Node 0 is the straggler at 50x: its frame, sent *first*,
        // must still arrive at node 2 after node 1's (1ms vs 50ms of
        // logical latency — a margin no scheduler hiccup closes).
        let mut cfg = NetConfig { latency_ns: 1_000_000, jitter_ns: 0, ..NetConfig::default() };
        cfg.chaos.straggler = Some(0);
        cfg.chaos.straggler_factor = 50.0;
        let (mut eps, meter) = SimNet::build_with_chaos(3, &cfg);
        let mut c = eps.pop().unwrap();
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(2, &Packet::pa(0, 0, vec![]));
        b.send(2, &Packet::pa(1, 1, vec![]));
        let (first, _) = c.recv_timeout(Duration::from_secs(2)).expect("fast frame");
        assert_eq!(first, 1, "the fast worker's frame must win");
        let (second, _) = c.recv_timeout(Duration::from_secs(2)).expect("slow frame");
        assert_eq!(second, 0);
        assert_eq!(meter.straggled_frames.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn chaos_replays_bit_identically_under_a_fixed_seed() {
        // One sender (FIFO into the fabric => a deterministic RNG
        // consumption order): the surviving seq set under drop +
        // bursts must be identical run to run.
        let run = || {
            let mut cfg = NetConfig { latency_ns: 0, jitter_ns: 0, ..NetConfig::default() };
            cfg.drop_prob = 0.3;
            cfg.chaos.burst_prob = 0.1;
            cfg.chaos.burst_ns = 50_000;
            cfg.chaos.burst_len = 4;
            cfg.seed = 42;
            let mut eps = SimNet::build(2, &cfg);
            let mut b = eps.pop().unwrap();
            let mut a = eps.pop().unwrap();
            for i in 0..200u16 {
                a.send(1, &Packet::pa(i, 0, vec![]));
            }
            drop(a); // fabric drains, then every survivor is queued
            let mut seqs = Vec::new();
            while let Some((_, pkt)) = b.recv_timeout(Duration::from_millis(500)) {
                seqs.push(pkt.seq);
            }
            seqs
        };
        let first = run();
        let second = run();
        assert!(!first.is_empty() && first.len() < 200, "drop must act: {}", first.len());
        assert_eq!(first, second, "fixed seed must replay the exact same survivor set");
    }

    #[test]
    fn disabled_chaos_keeps_the_passthrough_path() {
        // Chaos off + zero-fault config must still skip the fabric
        // thread entirely (the bitwise no-failure guarantee rides on
        // this), and the meter must stay zero.
        let (mut eps, meter) = SimNet::build_with_chaos(2, &fast_cfg());
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &Packet::pa(4, 0, vec![9]));
        let (_, pkt) = b.recv_timeout(Duration::from_secs(1)).expect("delivery");
        assert_eq!(pkt.seq, 4);
        assert!(matches!(a.path, Path::Direct(_)), "chaos off must not spawn a fabric");
        assert_eq!(meter.straggled_frames.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn latency_defers_delivery_logically() {
        // 2ms latency: the packet must not be deliverable immediately.
        let cfg = NetConfig { latency_ns: 2_000_000, jitter_ns: 0, ..NetConfig::default() };
        let mut eps = SimNet::build(2, &cfg);
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let t = Instant::now();
        a.send(1, &Packet::pa(0, 0, vec![]));
        let got = b.recv_timeout(Duration::from_secs(1));
        assert!(got.is_some());
        assert!(t.elapsed() >= Duration::from_millis(1), "delivered too early: {:?}", t.elapsed());
    }
}
