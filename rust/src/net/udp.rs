//! Real UDP transport on localhost — one socket per node.
//!
//! Gives the protocol stack a true datagram substrate (kernel buffers,
//! real truncation, genuine unreliability under pressure). Node `i` binds
//! `127.0.0.1:(base_port + i)`.
//!
//! **Steady-state parity with `SimNet` (§Perf L1):** the in-process
//! fabric moves payloads as shared `Arc<[i32]>` refcounts; the datagram
//! path must serialize, but it reuses its buffers — one encode scratch
//! per endpoint, a fixed rx buffer, and a [`PayloadPool`] for decoded
//! payloads — so localhost UDP runs are also allocation-free once warm
//! (provided the consumer drops each payload before the next receive,
//! which the pipeline does).
//!
//! **Poll-with-budget (§Perf L3):** the overlapped pipeline's drain
//! loop alternates zero- and short-budget polls with engine joins, so
//! the socket mode (non-blocking vs read-timeout) is cached and only
//! changed when a call actually needs a different one — the naive
//! toggle costs two `fcntl`/`setsockopt` round trips per probe.
//!
//! **Rx batch drain (`recvmmsg`):** after every successful receive the
//! endpoint siphons the already-queued burst out of the kernel into a
//! pre-sized user-space queue; subsequent polls pop the queue without
//! touching the socket. Bursts are the norm here — the switch
//! multicasts FAs and confirms back-to-back. On Linux the whole burst
//! costs **one `recvmmsg(MSG_DONTWAIT)` syscall** (declared directly
//! against libc, like `util/affinity.rs` — no crate dependency, no
//! socket-mode churn at all) over preallocated per-slot buffers; other
//! platforms fall back to the per-datagram nonblocking loop.

use super::{NodeId, Transport};
use crate::protocol::{Packet, PayloadPool};
use std::collections::VecDeque;
use std::net::{SocketAddr, UdpSocket};
use std::time::Duration;

/// Max datagram we ever send: header + 4KiB payload headroom.
const MAX_DGRAM: usize = 16 * 1024;

/// Max datagrams siphoned from the kernel per successful receive (the
/// first packet plus up to this many queued behind it). Must stay
/// below `PayloadPool::MAX_BUFS` so a full burst still decodes into
/// pooled buffers.
pub const RX_BATCH: usize = 16;

/// Linux `recvmmsg` batch receive — one syscall per burst. The libc
/// structures are declared directly (glibc and musl agree on the
/// x86-64/aarch64 layouts used here); everything is preallocated once
/// per endpoint, so the steady-state drain allocates nothing.
/// (`dead_code` allowed: several fields exist purely for the C ABI —
/// the kernel reads/writes them, Rust never does.)
#[cfg(target_os = "linux")]
#[allow(dead_code)]
mod mmsg {
    use super::MAX_DGRAM;

    /// `AF_INET` — the only family our localhost sockets speak.
    pub const AF_INET: u16 = 2;
    const MSG_DONTWAIT: i32 = 0x40;

    #[repr(C)]
    struct IoVec {
        base: *mut u8,
        len: usize,
    }

    /// IPv4 socket address as the kernel fills it (16 bytes).
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct SockAddrIn {
        pub sin_family: u16,
        /// Big-endian port.
        pub sin_port: u16,
        pub sin_addr: u32,
        pub sin_zero: [u8; 8],
    }

    #[repr(C)]
    struct MsgHdr {
        msg_name: *mut SockAddrIn,
        msg_namelen: u32,
        msg_iov: *mut IoVec,
        msg_iovlen: usize,
        msg_control: *mut u8,
        msg_controllen: usize,
        msg_flags: i32,
    }

    #[repr(C)]
    struct MMsgHdr {
        msg_hdr: MsgHdr,
        msg_len: u32,
    }

    extern "C" {
        // `timeout` is `*mut timespec`; we only ever pass NULL.
        fn recvmmsg(
            sockfd: i32,
            msgvec: *mut MMsgHdr,
            vlen: u32,
            flags: i32,
            timeout: *mut u8,
        ) -> i32;
        fn sendmmsg(sockfd: i32, msgvec: *mut MMsgHdr, vlen: u32, flags: i32) -> i32;
    }

    /// Preallocated receive slots: datagram buffers, source addresses,
    /// and the iovec/mmsghdr arrays pointing at them. The pointed-at
    /// storage is boxed (address-stable), so the arrays are built once
    /// and stay valid for the endpoint's lifetime, wherever the
    /// containing struct moves.
    pub struct Batch {
        cap: usize,
        bufs: Vec<Box<[u8; MAX_DGRAM]>>,
        addrs: Box<[SockAddrIn]>,
        /// Referenced by `hdrs`; never read directly.
        _iovs: Box<[IoVec]>,
        hdrs: Box<[MMsgHdr]>,
    }

    impl Batch {
        pub fn new(cap: usize) -> Self {
            let mut bufs: Vec<Box<[u8; MAX_DGRAM]>> =
                (0..cap).map(|_| Box::new([0u8; MAX_DGRAM])).collect();
            let zero = SockAddrIn { sin_family: 0, sin_port: 0, sin_addr: 0, sin_zero: [0; 8] };
            let mut addrs: Box<[SockAddrIn]> = vec![zero; cap].into_boxed_slice();
            let mut iovs: Box<[IoVec]> = bufs
                .iter_mut()
                .map(|b| IoVec { base: b.as_mut_ptr(), len: MAX_DGRAM })
                .collect::<Vec<_>>()
                .into_boxed_slice();
            let hdrs: Box<[MMsgHdr]> = (0..cap)
                .map(|i| MMsgHdr {
                    msg_hdr: MsgHdr {
                        msg_name: &mut addrs[i] as *mut SockAddrIn,
                        msg_namelen: std::mem::size_of::<SockAddrIn>() as u32,
                        msg_iov: &mut iovs[i] as *mut IoVec,
                        msg_iovlen: 1,
                        msg_control: std::ptr::null_mut(),
                        msg_controllen: 0,
                        msg_flags: 0,
                    },
                    msg_len: 0,
                })
                .collect::<Vec<_>>()
                .into_boxed_slice();
            Self { cap, bufs, addrs, _iovs: iovs, hdrs }
        }

        /// One nonblocking `recvmmsg`; returns how many datagrams
        /// landed (0 on would-block or error). Read them via
        /// [`Batch::slot`] before the next call.
        pub fn recv(&mut self, fd: i32) -> usize {
            for h in self.hdrs.iter_mut() {
                h.msg_hdr.msg_namelen = std::mem::size_of::<SockAddrIn>() as u32;
                h.msg_len = 0;
            }
            // SAFETY: every msgvec entry points at storage owned by
            // `self` (boxed, address-stable, sized as advertised);
            // vlen equals the entry count; MSG_DONTWAIT never blocks;
            // the kernel writes at most MAX_DGRAM bytes per slot and
            // reports lengths via msg_len.
            let n = unsafe {
                recvmmsg(fd, self.hdrs.as_mut_ptr(), self.cap as u32, MSG_DONTWAIT, std::ptr::null_mut())
            };
            if n <= 0 {
                0
            } else {
                n as usize
            }
        }

        /// Datagram `i` of the last [`Batch::recv`]: `(source, bytes)`.
        pub fn slot(&self, i: usize) -> (&SockAddrIn, &[u8]) {
            let len = (self.hdrs[i].msg_len as usize).min(MAX_DGRAM);
            (&self.addrs[i], &self.bufs[i][..len])
        }
    }

    /// Batched multicast tx — the `sendmmsg` twin of [`Batch`]: one
    /// encoded datagram fanned out to N localhost destinations in one
    /// syscall. Unlike rx, the kernel copies everything during the
    /// call, so the header arrays need only outlive it; they are
    /// reusable `Vec`s (allocation-free once warm), repointed at the
    /// caller's encode scratch each send.
    pub struct TxBatch {
        addrs: Vec<SockAddrIn>,
        iovs: Vec<IoVec>,
        hdrs: Vec<MMsgHdr>,
    }

    impl TxBatch {
        pub fn new() -> Self {
            Self { addrs: Vec::new(), iovs: Vec::new(), hdrs: Vec::new() }
        }

        /// Send `bytes` to `127.0.0.1:(base_port + dst)` for every
        /// `dst` in `dsts` via one `sendmmsg` (looping only if the
        /// kernel accepts a partial batch). Returns datagrams
        /// accepted; shortfalls are packet loss, which the protocol
        /// tolerates by contract.
        pub fn send(&mut self, fd: i32, bytes: &[u8], base_port: u16, dsts: &[usize]) -> usize {
            let n = dsts.len();
            self.addrs.clear();
            self.addrs.extend(dsts.iter().map(|&d| SockAddrIn {
                sin_family: AF_INET,
                sin_port: (base_port + d as u16).to_be(),
                sin_addr: u32::from_be(0x7F00_0001), // 127.0.0.1
                sin_zero: [0; 8],
            }));
            self.iovs.clear();
            self.iovs.extend((0..n).map(|_| IoVec {
                // The kernel only reads from a tx iovec; the mutable
                // pointer is an ABI artifact shared with the rx path.
                base: bytes.as_ptr() as *mut u8,
                len: bytes.len(),
            }));
            // Headers are built only after `addrs`/`iovs` hold their
            // final length, so the pointers taken here cannot be
            // invalidated by a later reallocation.
            self.hdrs.clear();
            for i in 0..n {
                self.hdrs.push(MMsgHdr {
                    msg_hdr: MsgHdr {
                        msg_name: &mut self.addrs[i] as *mut SockAddrIn,
                        msg_namelen: std::mem::size_of::<SockAddrIn>() as u32,
                        msg_iov: &mut self.iovs[i] as *mut IoVec,
                        msg_iovlen: 1,
                        msg_control: std::ptr::null_mut(),
                        msg_controllen: 0,
                        msg_flags: 0,
                    },
                    msg_len: 0,
                });
            }
            let mut sent = 0;
            while sent < n {
                // SAFETY: every msgvec entry points at storage owned
                // by `self` or at the caller's `bytes`, all live for
                // the duration of the call; vlen matches the entry
                // count; the kernel copies before returning.
                let r = unsafe {
                    sendmmsg(fd, self.hdrs.as_mut_ptr().add(sent), (n - sent) as u32, 0)
                };
                if r <= 0 {
                    break;
                }
                sent += r as usize;
            }
            sent
        }
    }
}

/// Cached socket mode (see the module docs' poll-with-budget note).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    /// `O_NONBLOCK` set: receives return `WouldBlock` immediately.
    NonBlocking,
    /// Blocking with `SO_RCVTIMEO` set to the given budget.
    Timeout(Duration),
}

/// A UDP endpoint implementing [`Transport`].
pub struct UdpEndpoint {
    node: NodeId,
    base_port: u16,
    socket: UdpSocket,
    scratch: Vec<u8>,
    rxbuf: [u8; MAX_DGRAM],
    pool: PayloadPool,
    /// Last mode applied to the socket (`None` = fresh blocking socket).
    mode: Option<Mode>,
    /// Batch-drained packets awaiting delivery (≤ [`RX_BATCH`]).
    rxq: VecDeque<(NodeId, Packet)>,
    /// `recvmmsg` slots, allocated on the first drain (send-only
    /// endpoints never pay for them).
    #[cfg(target_os = "linux")]
    batch: Option<mmsg::Batch>,
    /// `sendmmsg` headers, allocated on the first multicast (unicast
    /// endpoints never pay for them).
    #[cfg(target_os = "linux")]
    tx: Option<mmsg::TxBatch>,
}

/// Build `nodes` endpoints on consecutive localhost ports starting at
/// `base_port`. Fails if any port is taken.
pub fn build(nodes: usize, base_port: u16) -> std::io::Result<Vec<UdpEndpoint>> {
    (0..nodes).map(|node| bind_one(node, base_port)).collect()
}

/// Bind the single endpoint for `node` (process mode: each OS process
/// owns exactly its own socket; peers are addressed by node id on the
/// shared `base_port` plan). Fails if the port is taken — a stale
/// process from a previous run, or a base-port collision.
pub fn bind_one(node: NodeId, base_port: u16) -> std::io::Result<UdpEndpoint> {
    let socket = UdpSocket::bind(("127.0.0.1", base_port + node as u16))?;
    socket.set_nonblocking(false)?;
    Ok(UdpEndpoint {
        node,
        base_port,
        socket,
        scratch: Vec::new(),
        rxbuf: [0; MAX_DGRAM],
        pool: PayloadPool::new(),
        mode: None,
        rxq: VecDeque::with_capacity(RX_BATCH),
        #[cfg(target_os = "linux")]
        batch: None,
        #[cfg(target_os = "linux")]
        tx: None,
    })
}

impl UdpEndpoint {
    fn addr_of(&self, node: NodeId) -> SocketAddr {
        SocketAddr::from(([127, 0, 0, 1], self.base_port + node as u16))
    }

    fn node_of(&self, addr: SocketAddr) -> Option<NodeId> {
        let port = addr.port();
        port.checked_sub(self.base_port).map(|p| p as NodeId)
    }

    /// Put the socket in `want` mode, skipping the syscalls when it is
    /// already there. The cache is invalidated before a transition and
    /// set only after full success: a partially applied two-syscall
    /// change (nonblocking cleared, timeout set failed) must read as
    /// "unknown", not as the old mode, or a later zero-budget poll
    /// would skip the syscalls and block forever.
    fn set_mode(&mut self, want: Mode) -> Option<()> {
        if self.mode == Some(want) {
            return Some(());
        }
        let prev = self.mode.take();
        match want {
            Mode::NonBlocking => self.socket.set_nonblocking(true).ok()?,
            Mode::Timeout(t) => {
                if !matches!(prev, Some(Mode::Timeout(_))) {
                    self.socket.set_nonblocking(false).ok()?;
                }
                self.socket.set_read_timeout(Some(t)).ok()?;
            }
        }
        self.mode = Some(want);
        Some(())
    }

    /// Batch-drained packets waiting in user space (diagnostics).
    pub fn rx_queued(&self) -> usize {
        self.rxq.len()
    }

    /// Rx batch drain (see module docs): siphon whatever the kernel
    /// already queued behind a successful receive into the user-space
    /// queue. Linux: one `recvmmsg(MSG_DONTWAIT)` syscall for the
    /// whole burst, no socket-mode changes.
    #[cfg(target_os = "linux")]
    fn drain_burst(&mut self) {
        use std::os::unix::io::AsRawFd;
        let fd = self.socket.as_raw_fd();
        let UdpEndpoint { pool, rxq, base_port, batch, .. } = self;
        let batch = batch.get_or_insert_with(|| mmsg::Batch::new(RX_BATCH));
        let n = batch.recv(fd);
        for i in 0..n {
            let (addr, bytes) = batch.slot(i);
            let Ok(pkt) = Packet::decode_with(bytes, pool) else {
                continue; // skip garbage, keep the rest of the burst
            };
            if addr.sin_family != mmsg::AF_INET {
                continue;
            }
            if let Some(src) = u16::from_be(addr.sin_port).checked_sub(*base_port) {
                rxq.push_back((src as NodeId, pkt));
            }
        }
    }

    /// Portable fallback: per-datagram nonblocking receives over the
    /// cached socket mode. (A timed receive leaves the socket cached
    /// nonblocking — which the AggClient's poll loop would have
    /// switched to on its next call anyway, so in sparse traffic the
    /// drain's net cost is one EWOULDBLOCK recv.)
    #[cfg(not(target_os = "linux"))]
    fn drain_burst(&mut self) {
        if self.set_mode(Mode::NonBlocking).is_none() {
            return;
        }
        while self.rxq.len() < RX_BATCH {
            let Ok((n, from)) = self.socket.recv_from(&mut self.rxbuf) else { break };
            let Ok(pkt) = Packet::decode_with(&self.rxbuf[..n], &mut self.pool) else {
                continue; // skip garbage, keep draining
            };
            if let Some(src) = self.node_of(from) {
                self.rxq.push_back((src, pkt));
            }
        }
    }
}

impl Transport for UdpEndpoint {
    fn send(&mut self, dst: NodeId, pkt: &Packet) {
        let mut scratch = std::mem::take(&mut self.scratch);
        pkt.encode(&mut scratch);
        // Unreliable by contract: ignore send errors. (A non-blocking
        // send mode never blocks on UDP anyway.)
        let _ = self.socket.send_to(&scratch, self.addr_of(dst));
        self.scratch = scratch;
    }

    /// Batched multicast (see module docs): encode once, hand the
    /// kernel the whole fan-out in one `sendmmsg` on Linux; the
    /// portable fallback is the trait's per-destination loop.
    fn send_many(&mut self, dsts: &[NodeId], pkt: &Packet) {
        #[cfg(target_os = "linux")]
        if dsts.len() > 1 {
            use std::os::unix::io::AsRawFd;
            let fd = self.socket.as_raw_fd();
            let base_port = self.base_port;
            let mut scratch = std::mem::take(&mut self.scratch);
            pkt.encode(&mut scratch);
            let tx = self.tx.get_or_insert_with(mmsg::TxBatch::new);
            // Unreliable by contract: a short batch is packet loss.
            let _ = tx.send(fd, &scratch, base_port, dsts);
            self.scratch = scratch;
            return;
        }
        for &dst in dsts {
            self.send(dst, pkt);
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Option<(NodeId, Packet)> {
        // Earlier batch drains deliver first — no syscall at all.
        if let Some(item) = self.rxq.pop_front() {
            return Some(item);
        }
        if timeout.is_zero() {
            self.set_mode(Mode::NonBlocking)?;
        } else {
            self.set_mode(Mode::Timeout(timeout))?;
        }
        let (n, from) = self.socket.recv_from(&mut self.rxbuf).ok()?;
        let pkt = Packet::decode_with(&self.rxbuf[..n], &mut self.pool).ok()?;
        let first = (self.node_of(from)?, pkt);
        self.drain_burst();
        Some(first)
    }

    fn node(&self) -> NodeId {
        self.node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Port ranges spaced out so parallel test binaries don't collide.
    const BASE: u16 = 47800;

    #[test]
    fn roundtrip_between_two_nodes() {
        let mut eps = build(2, BASE).expect("bind");
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &Packet::pa(42, 0, vec![7, -9]));
        let (src, pkt) = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        assert_eq!(src, 0);
        assert_eq!(pkt.seq, 42);
        assert_eq!(pkt.payload[..], [7, -9]);
    }

    #[test]
    fn timeout_returns_none() {
        let mut eps = build(1, BASE + 16).expect("bind");
        let mut a = eps.pop().unwrap();
        assert!(a.recv_timeout(Duration::from_millis(50)).is_none());
    }

    #[test]
    fn try_recv_nonblocking() {
        let mut eps = build(2, BASE + 32).expect("bind");
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        assert!(b.try_recv().is_none());
        a.send(1, &Packet::ack(5, 0));
        // allow the kernel a moment
        let mut got = None;
        for _ in 0..100 {
            got = b.try_recv();
            if got.is_some() {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let (_, pkt) = got.expect("delivery");
        assert!(!pkt.is_agg);
        assert_eq!(pkt.seq, 5);
    }

    #[test]
    fn steady_state_receive_reuses_the_decode_buffer() {
        // Drop each payload before the next receive (the pipeline's
        // pattern): the second decode must land in the same pooled
        // allocation — the UDP path's SimNet-parity contract.
        let mut eps = build(2, BASE + 64).expect("bind");
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send(1, &Packet::pa(1, 0, vec![1, 2, 3, 4]));
        let (_, p1) = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        assert_eq!(p1.payload[..], [1, 2, 3, 4]);
        let ptr = p1.payload.as_ptr();
        drop(p1);
        a.send(1, &Packet::pa(2, 0, vec![5, 6, 7, 8]));
        let (_, p2) = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        assert_eq!(p2.payload[..], [5, 6, 7, 8]);
        assert_eq!(p2.payload.as_ptr(), ptr, "decode must reuse the pooled buffer");
    }

    #[test]
    fn mixed_zero_and_timed_polls_share_the_mode_cache() {
        // The depth-2 drain pattern: bursts of non-blocking probes
        // interleaved with short timed waits. The cached-mode socket
        // must deliver correctly across every transition.
        let mut eps = build(2, BASE + 80).expect("bind");
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        for _ in 0..4 {
            assert!(b.try_recv().is_none());
        }
        a.send(1, &Packet::pa(1, 0, vec![1]));
        let (_, p) = b.recv_timeout(Duration::from_secs(2)).expect("timed after zero");
        assert_eq!(p.seq, 1);
        assert!(b.recv_timeout(Duration::from_millis(20)).is_none());
        a.send(1, &Packet::pa(2, 0, vec![2]));
        let mut got = None;
        for _ in 0..200 {
            got = b.try_recv();
            if got.is_some() {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(got.expect("zero after timed").1.seq, 2);
    }

    #[test]
    fn burst_drains_into_user_space_queue() {
        // Four packets already in the kernel buffer: one receive call
        // must deliver the first and siphon the rest into the rx queue,
        // so later polls pop without a syscall. Retried because
        // localhost delivery is fast but not instantaneous.
        let mut eps = build(2, BASE + 96).expect("bind");
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        let mut queued = 0;
        for _ in 0..50 {
            for i in 0u16..4 {
                a.send(1, &Packet::pa(i, 0, vec![i as i32]));
            }
            std::thread::sleep(Duration::from_millis(50));
            let _first = b.recv_timeout(Duration::from_secs(2)).expect("burst head");
            queued = b.rx_queued();
            // Drain the remainder (queue first, then the socket).
            let mut got = 1;
            while got < 4 && b.recv_timeout(Duration::from_millis(200)).is_some() {
                got += 1;
            }
            assert_eq!(got, 4, "all burst packets must arrive");
            if queued > 0 {
                break;
            }
        }
        assert!(queued > 0, "a settled 4-packet burst must batch-drain into the rx queue");
        assert_eq!(b.rx_queued(), 0, "queue fully delivered");
    }

    #[test]
    fn multicast_send_many_reaches_every_destination() {
        // The batched tx twin of the rx burst drain: one `send_many`
        // per round from the "switch" endpoint must land the same
        // payload on every worker endpoint (Linux: one `sendmmsg`
        // syscall per round; elsewhere: the portable loop).
        let mut eps = build(4, BASE + 112).expect("bind");
        let mut sw = eps.pop().unwrap(); // node 3 plays the switch
        let dsts: Vec<NodeId> = (0..3).collect();
        for round in 0u16..4 {
            sw.send_many(&dsts, &Packet::pa(round, 3, vec![round as i32, -7]));
        }
        for ep in eps.iter_mut() {
            let mut seqs = Vec::new();
            for _ in 0..4 {
                let (src, pkt) =
                    ep.recv_timeout(Duration::from_secs(2)).expect("fan-out delivery");
                assert_eq!(src, 3);
                assert_eq!(pkt.payload[..], [pkt.seq as i32, -7]);
                seqs.push(pkt.seq);
            }
            seqs.sort_unstable();
            assert_eq!(seqs, [0, 1, 2, 3], "every round reaches every destination");
        }
    }

    #[test]
    fn send_many_to_one_destination_matches_send() {
        let mut eps = build(2, BASE + 128).expect("bind");
        let mut b = eps.pop().unwrap();
        let mut a = eps.pop().unwrap();
        a.send_many(&[1], &Packet::pa(9, 0, vec![5]));
        let (src, pkt) = b.recv_timeout(Duration::from_secs(2)).expect("delivery");
        assert_eq!((src, pkt.seq), (0, 9));
        assert_eq!(pkt.payload[..], [5]);
    }

    #[test]
    fn garbage_datagram_is_skipped() {
        let mut eps = build(2, BASE + 48).expect("bind");
        let mut b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        // raw junk straight to b's socket
        let junk = UdpSocket::bind("127.0.0.1:0").unwrap();
        junk.send_to(&[1, 2, 3], ("127.0.0.1", BASE + 48 + 1)).unwrap();
        drop(a);
        assert!(b.recv_timeout(Duration::from_millis(100)).is_none());
    }
}
