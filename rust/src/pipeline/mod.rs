//! Forward–Communication–Backward micro-batch pipeline (paper C2,
//! Fig. 2c) plus the data preparation it runs over.
//!
//! A mini-batch of `B` samples is split into `B/MB` micro-batches. The
//! worker issues forward passes back-to-back; each finished micro-batch's
//! PA is sent to the switch immediately (non-blocking slot claim), and
//! full activations are drained opportunistically between forwards, so
//! communication of micro-batch *j* overlaps the forward of *j+1..* and
//! the backward of earlier micro-batches — while gradient accumulation
//! keeps synchronous-SGD semantics (the model updates only at the
//! mini-batch boundary, after every FA arrived).
//!
//! **Zero-allocation steady state (§Perf L1):** everything the loop
//! touches per micro-batch is preallocated. [`PreparedShard`] holds the
//! bit-plane image only (the backward replays planes — no dequantized
//! copy, an ~8x memory cut at P=4); [`PipelineScratch`] carries the PA
//! accumulator, wire encode/decode buffers, and the seq→micro-batch
//! map; `AggClient` recycles payload buffers through an `Arc` pool.
//! After one warm-up pass over every round slot, [`run_minibatch`]
//! performs **zero heap allocations** per micro-batch on the native
//! backend, at every pipeline depth (enforced by
//! `tests/alloc_steady_state.rs` with a counting allocator).
//!
//! **Engine execution (§Perf L2):** per-engine compute state — model
//! and gradient slices, one `Compute` per engine, the per-engine
//! forward buffer — lives in the [`EngineRunner`], not here. The
//! pipeline drives it through three calls per micro-batch lifecycle:
//! `forward` (PA = ordered engine fan-in), a slot-indexed backward
//! (plane replay against the decoded FA, gradients accumulated
//! engine-locally per round slot), and `update_slot` at each round
//! boundary. With `engine_threads > 1` those calls dispatch to the
//! runner's persistent thread pool over preallocated Condvar job slots
//! (see `engine::runner`), so engine parallelism costs no steady-state
//! allocation and changes no numerics (ordered fan-in keeps f32 sums
//! bit-identical).
//!
//! **Round ring (§Perf L3, `pipeline_depth`):** at depth 1 (the
//! default) rounds are synchronous: [`run_minibatch`] forwards, drains
//! every FA (running backwards as they land), updates, and returns —
//! bit-compatible with the pre-overlap pipeline. At depth `D ∈ 2..=8`
//! the scratch carries a **ring of D round slots**: up to D-1 rounds
//! stay in flight between calls, each with its own seq→micro-batch
//! map, parked-FA list (payload refcounts, decoded only at dispatch),
//! accumulated loss, and deferred update scale. Ring slot `i` maps 1:1
//! onto the runner's gradient slot `i`, so *any* in-flight round's
//! backwards can run as soon as its FAs land — before older rounds
//! have retired — and one slow AllReduce stalls nothing but its own
//! round. A [`run_minibatch`] call begins round *k* in the next free
//! slot, forwards and ships it while feeding arrived FAs of all live
//! rounds to the engines ([`EngineRunner::dispatch_backward`] /
//! [`EngineRunner::try_reap_backward`] — the dispatcher never blocks
//! while the network is quiet and the engines are busy), and retires
//! the *oldest* round only when the ring is full: join its remaining
//! backwards, apply its update, free its slot.
//!
//! The contract is **bounded staleness**: a round's forwards read a
//! model at most D-1 updates older than the synchronous schedule would
//! (observed per round in [`crate::metrics::DepthStats`]), updates
//! apply in round order, and [`flush_round`] (called at every epoch
//! boundary) drains the whole ring so staleness never crosses an epoch
//! and per-epoch loss attribution stays exact. Gradient state never
//! mixes between rounds: each ring slot accumulates into its own
//! engine-side gradient buffer, cleared by its own update.
//!
//! **Generation bumps (membership changes):** when the `AggClient`
//! observes a cluster-generation bump (a worker was evicted, left, or
//! rejoined — see `crate::protocol`), every in-flight round is dead:
//! its FAs will never arrive, and its half-accumulated gradients
//! belong to the old membership. [`run_minibatch`] and [`flush_round`]
//! then **drain the ring cleanly instead of wedging**: already
//! dispatched backwards are joined (never abandoned mid-engine), every
//! gradient slot is cleared without applying, every ring slot is
//! reset, and the call returns 0.0 with [`AggClient::interrupted`]
//! still set — the trainer checks it after every call and falls back
//! to its checkpoint/restart path. No deferred backward ever crosses a
//! membership change, and no stale-generation FA is ever applied (the
//! client drops those before they reach this module).

use crate::data::partition::{vertical, VerticalShard};
use crate::data::quantize::{pack_rows, PackedBatch, LANE};
use crate::engine::EngineRunner;
use crate::glm::Loss;
use crate::metrics::{DepthStats, RoundNetStats};
use crate::net::Transport;
use crate::protocol::{decode_activations_into, encode_activations_into};
use crate::worker::{AggClient, Event};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard cap on waiting for stragglers before declaring the cluster dead.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// One engine's slice of the worker's model partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSlice {
    /// Offsets within the worker partition.
    pub lo: usize,
    pub hi: usize,
    /// Lane-padded width (engine datapath / artifact width).
    pub d_pad: usize,
}

/// One prepared micro-batch: per-engine bit-planes (forward *and*
/// plane-replay backward) plus labels.
#[derive(Debug, Clone)]
pub struct PreparedMicro {
    pub per_engine: Vec<PackedBatch>,
    pub y: Vec<f32>,
}

/// A worker's shard, quantized and packed once up front — the software
/// twin of the FPGA's bit-weaved HBM image.
#[derive(Debug, Clone)]
pub struct PreparedShard {
    pub engines: Vec<EngineSlice>,
    pub micro: Vec<PreparedMicro>,
    pub mb: usize,
    pub n: usize,
}

impl PreparedShard {
    /// Quantize + pack `shard` for `n_engines` engines at micro-batch
    /// size `mb` and the given bit-weaving precision.
    ///
    /// Engine slices are padded straight to the AOT artifact widths
    /// (256/1024/4096) when they fit: padding is inert for both
    /// backends (zero words), and it makes the PJRT path zero-copy
    /// (§Perf L1 — no per-call re-padding).
    pub fn prepare(shard: &VerticalShard, n_engines: usize, mb: usize, precision: u32) -> Self {
        let width = shard.slice.width();
        let n_engines = n_engines.min(width); // degenerate tiny shards
        let artifact_pad = |lane_pad: usize| -> usize {
            for v in [256usize, 1024, 4096] {
                if lane_pad <= v {
                    return v;
                }
            }
            lane_pad
        };
        let slices: Vec<EngineSlice> = vertical(width, n_engines, LANE)
            .into_iter()
            .map(|s| EngineSlice { lo: s.lo, hi: s.hi, d_pad: artifact_pad(s.padded) })
            .collect();
        let n_micro = shard.n / mb;
        let mut micro = Vec::with_capacity(n_micro);
        let mut scratch = Vec::new();
        for m in 0..n_micro {
            let rows = shard.rows(m * mb, (m + 1) * mb);
            let mut per_engine = Vec::with_capacity(slices.len());
            for s in &slices {
                let ew = s.hi - s.lo;
                scratch.clear();
                for i in 0..mb {
                    scratch.extend_from_slice(&rows[i * width + s.lo..i * width + s.hi]);
                }
                per_engine.push(pack_rows(&scratch, mb, ew, s.d_pad, precision));
            }
            micro.push(PreparedMicro {
                per_engine,
                y: shard.labels[m * mb..(m + 1) * mb].to_vec(),
            });
        }
        PreparedShard { engines: slices, micro, mb, n: shard.n }
    }

    pub fn micro_batches(&self) -> usize {
        self.micro.len()
    }
}

/// Mutable training state of one worker: per-engine model and gradient.
/// The [`EngineRunner`] keeps its own (per-round-slot) copy of this
/// shape internally; `WorkerState` is used directly by the reference
/// oracle and tests.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub x: Vec<Vec<f32>>,
    pub g: Vec<Vec<f32>>,
}

impl WorkerState {
    pub fn zeros(prep: &PreparedShard) -> Self {
        let x = prep.engines.iter().map(|s| vec![0.0f32; s.d_pad]).collect::<Vec<_>>();
        let g = x.clone();
        Self { x, g }
    }

    /// Stitch the (unpadded) model partition back together.
    pub fn model(&self, prep: &PreparedShard) -> Vec<f32> {
        stitch_model(&prep.engines, &self.x)
    }
}

/// Stitch per-engine (padded) model slices back into the unpadded
/// worker partition — the one place the padding convention is undone
/// (shared by [`WorkerState::model`] and the runner's serial export).
pub fn stitch_model(engines: &[EngineSlice], x: &[Vec<f32>]) -> Vec<f32> {
    let mut out = Vec::new();
    for (s, xe) in engines.iter().zip(x) {
        out.extend_from_slice(&xe[..s.hi - s.lo]);
    }
    out
}

/// Counters from one mini-batch run.
#[derive(Debug, Default, Clone, Copy)]
pub struct PipelineStats {
    /// Micro-batches whose FA arrived only in the final drain (no
    /// overlap left to exploit). Depth-1 path only.
    pub drained: u64,
    /// Micro-batches overlapped with later forwards. Depth-1 path only.
    pub overlapped: u64,
    /// Overlap path: backward jobs dispatched to the engine ring while
    /// the dispatcher kept pumping the transport.
    pub overlapped_backwards: u64,
    /// Overlap path: FAs that arrived for a round *behind* the
    /// retirement head — work the synchronous schedule would already
    /// have needed, deferred into a later call.
    pub deferred_fas: u64,
    /// Overlap path: rounds retired through the deferred update path
    /// (including the flush at epoch boundaries).
    pub deferred_rounds: u64,
    /// Staleness histogram + in-flight-depth gauge, one observation per
    /// round (see [`DepthStats`]).
    pub depth: DepthStats,
    /// Per-round network health, sampled once per round from cumulative
    /// `AggStats` deltas — never per packet (see [`RoundNetStats`]).
    pub net: RoundNetStats,
}

impl PipelineStats {
    /// Fold another worker's counters into this one.
    pub fn merge(&mut self, other: &Self) {
        self.drained += other.drained;
        self.overlapped += other.overlapped;
        self.overlapped_backwards += other.overlapped_backwards;
        self.deferred_fas += other.deferred_fas;
        self.deferred_rounds += other.deferred_rounds;
        self.depth.merge(&other.depth);
        self.net.merge(&other.net);
    }
}

/// One mini-batch round carried across [`run_minibatch`] calls by the
/// overlapped pipeline: its aggregation traffic is still in flight
/// while later rounds' forwards run. All buffers are reused round over
/// round, so the overlapped path stays allocation-free in steady state.
/// Ring slot `i` accumulates gradients in the runner's slot `i`.
#[derive(Debug, Default)]
struct PendingRound {
    active: bool,
    /// Micro-batch range `[first, first + count)`.
    first: usize,
    count: usize,
    /// Deferred update scale, applied when the round retires.
    inv_b: f32,
    /// Loss accumulated from joined backwards.
    loss_sum: f32,
    /// Backwards fully executed (dispatched and joined).
    done: usize,
    /// seq -> micro-batch index, FAs still in flight.
    pending: Vec<(u16, usize)>,
    /// Arrived FAs awaiting the engines (payload refcounts; decoded at
    /// dispatch): the engine ring was full when they landed.
    ready: Vec<(usize, Arc<[i32]>)>,
}

impl PendingRound {
    fn begin(&mut self, first: usize, count: usize, inv_b: f32) {
        debug_assert!(!self.active, "round slot still in flight");
        self.active = true;
        self.first = first;
        self.count = count;
        self.inv_b = inv_b;
        self.loss_sum = 0.0;
        self.done = 0;
        self.pending.clear();
        self.pending.reserve(count);
        self.ready.clear();
        self.ready.reserve(count);
    }

    fn retire(&mut self) {
        debug_assert!(self.done == self.count && self.pending.is_empty() && self.ready.is_empty());
        self.active = false;
    }
}

/// Reusable buffers for [`run_minibatch`]. Construct once per worker;
/// every capacity is established while the ring warms up (each of the
/// depth slots on its first use), after which the steady-state loop
/// never allocates. The scratch also fixes the pipeline depth for its
/// worker (the round ring it carries is meaningless across a depth
/// change) — it must match the [`EngineRunner`]'s round count.
#[derive(Debug)]
pub struct PipelineScratch {
    /// Engine-summed partial activations (MB wide).
    pa: Vec<f32>,
    /// Fixed-point wire payload (MB wide).
    payload: Vec<i32>,
    /// Decoded full activations (MB wide).
    fa: Vec<f32>,
    /// In-flight seq -> micro-batch index (≤ window entries; linear scan
    /// beats hashing at this size and never rehashes/allocates).
    /// Depth-1 path only — the overlap path tracks seqs per round.
    pending: Vec<(u16, usize)>,
    /// Overlap depth D: 1 = synchronous rounds (bit-compatible with the
    /// pre-overlap pipeline), 2..=8 = up to D-1 rounds of in-flight
    /// forward–communication–backward overlap.
    depth: usize,
    /// Round ring, one slot per depth level; slot `i` == runner
    /// gradient slot `i`.
    rounds: Vec<PendingRound>,
    /// Ring index of the oldest in-flight round.
    head: usize,
    /// Number of in-flight rounds (`<= depth - 1` between calls).
    live: usize,
}

impl Default for PipelineScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl PipelineScratch {
    /// Synchronous (depth-1) scratch — the bit-compatible schedule.
    pub fn new() -> Self {
        Self::with_depth(1)
    }

    /// `depth` ∈ 1..=8: 1 runs rounds synchronously, D ≥ 2 keeps up to
    /// D-1 rounds in flight across calls (bounded staleness D-1; see
    /// the module docs).
    pub fn with_depth(depth: usize) -> Self {
        assert!((1..=8).contains(&depth), "pipeline depth must be in 1..=8, got {depth}");
        Self {
            pa: Vec::new(),
            payload: Vec::new(),
            fa: Vec::new(),
            pending: Vec::new(),
            depth,
            rounds: (0..depth).map(|_| PendingRound::default()).collect(),
            head: 0,
            live: 0,
        }
    }

    /// The overlap depth this scratch drives.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Rounds currently in flight (0 between calls at depth 1).
    pub fn in_flight_rounds(&self) -> usize {
        self.live
    }
}

/// Generation-bump abort, overlap path: join every dispatched backward
/// (an engine job is never abandoned mid-flight), discard every
/// gradient slot, and reset the whole round ring — the dead
/// generation's rounds must neither wedge the drain nor leak
/// half-accumulated gradients into the resumed training. The caller
/// resets the ring cursors and returns 0.0; the trainer sees the
/// pending bump via [`AggClient::interrupted`].
fn abort_ring(runner: &mut EngineRunner, rounds: &mut [PendingRound]) {
    while runner.outstanding_backwards() > 0 {
        let _ = runner.join_backward();
    }
    runner.clear_gradients();
    for r in rounds.iter_mut() {
        r.active = false;
        r.count = 0;
        r.done = 0;
        r.loss_sum = 0.0;
        r.pending.clear();
        r.ready.clear();
    }
}

/// Generation-bump abort, depth-1 path: the current mini-batch dies
/// (its remaining FAs will never arrive); drop its seq map and the
/// partial gradient.
fn abort_sync(runner: &mut EngineRunner, pending: &mut Vec<(u16, usize)>) {
    pending.clear();
    runner.clear_gradients();
}

/// Apply one FA event: decode, then loss + plane-replay backward on the
/// runner (fanned out across engine threads when the pool is active).
/// Depth-1 path: blocking backward against gradient slot 0.
#[allow(clippy::too_many_arguments)]
fn on_event(
    ev: Event,
    runner: &mut EngineRunner,
    pending: &mut Vec<(u16, usize)>,
    fa_buf: &mut Vec<f32>,
    loss: Loss,
    lr: f32,
    loss_sum: &mut f32,
    done: &mut usize,
) {
    let Event::Fa { seq, payload } = ev else { return };
    let Some(pos) = pending.iter().position(|(s, _)| *s == seq) else { return };
    let (_, idx) = pending.swap_remove(pos);
    decode_activations_into(&payload, fa_buf);
    *loss_sum += runner.backward(idx, fa_buf, lr, loss);
    *done += 1;
}

/// Run one mini-batch (micro-batches `[first, first + count)`) through
/// the FCB pipeline. Returns the summed training loss of the mini-batch
/// at depth 1; at depth ≥ 2 it returns the loss of the round *retired*
/// this call (0.0 while the ring is still filling at the start of an
/// epoch), and [`flush_round`] returns the tail.
///
/// At depth 1 the runner enters with zeroed gradients (fresh from
/// construction or from the previous `update`, which clears them) and
/// leaves the same way — gradient state never leaks across
/// mini-batches. At depth ≥ 2 the call leaves up to depth-1 rounds in
/// flight in the scratch; their gradients retire (in round order) on
/// later calls or at the flush.
#[allow(clippy::too_many_arguments)]
pub fn run_minibatch<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    // Per-round network health: one cumulative-counter delta per round,
    // not a sample per packet (noise-free under loss).
    let retrans_mark = agg.stats.retransmits;
    let loss_out = if scratch.depth >= 2 {
        run_overlapped(runner, agg, first, count, loss, lr, stats, scratch)
    } else {
        run_synchronous(runner, agg, first, count, loss, lr, stats, scratch)
    };
    stats.net.observe_round(agg.stats.retransmits - retrans_mark);
    loss_out
}

/// The depth-1 schedule: forward + ship every micro-batch, drain every
/// FA (backwards run as they land), update, return. Bit-compatible with
/// the pre-overlap pipeline.
#[allow(clippy::too_many_arguments)]
fn run_synchronous<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    let mb = runner.prep().mb;
    let PipelineScratch { pa, payload, fa, pending, .. } = scratch;
    pa.resize(mb, 0.0);
    // `fa` and `payload` size themselves inside the into-codecs (clear +
    // extend), so their capacity is warm after the first micro-batch.
    pending.clear();
    pending.reserve(count);
    let mut loss_sum = 0.0f32;
    let mut done = 0usize;
    stats.depth.observe_round(0, 1);

    // Stage 1+2 interleaved: forward each micro-batch, ship PA, drain FAs.
    for j in 0..count {
        let idx = first + j;
        // Forward across engines; PA is the engine-sum (paper §4.1.3),
        // fanned in over engine outputs in engine order.
        runner.forward(idx, pa);
        encode_activations_into(pa, payload);
        // Claim a slot; pump the network while backpressured.
        let seq = loop {
            if agg.interrupted() {
                // Membership changed under us: this round is dead.
                abort_sync(runner, pending);
                return 0.0;
            }
            if let Some(seq) = agg.try_send_pa(payload) {
                break seq;
            }
            if let Some(ev) = agg.poll(Duration::from_micros(200)) {
                on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
            }
        };
        pending.push((seq, idx));
        // Opportunistic drain: overlap communication with later forwards.
        while let Some(ev) = agg.poll(Duration::ZERO) {
            let before = done;
            on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
            if done > before && j + 1 < count {
                stats.overlapped += 1;
            }
        }
        if agg.interrupted() {
            abort_sync(runner, pending);
            return 0.0;
        }
    }

    // Stage 3 tail: block for the remaining FAs.
    let deadline = std::time::Instant::now() + DRAIN_TIMEOUT;
    while done < count {
        if agg.interrupted() {
            abort_sync(runner, pending);
            return 0.0;
        }
        let Some(ev) = agg.poll(Duration::from_millis(20)) else {
            assert!(
                std::time::Instant::now() < deadline,
                "drain timeout: worker {} missing {} of {count} micro-batches; \
                 pending seqs {:?}; in_flight {}; stats {:?}",
                agg.worker(),
                count - done,
                pending.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
                agg.in_flight(),
                agg.stats,
            );
            continue;
        };
        let before = done;
        on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
        if done > before {
            stats.drained += 1;
        }
    }

    // Model update at the mini-batch boundary (synchronous SGD
    // preserved); the runner zeroes its gradient slot for the next
    // window.
    let inv_b = 1.0 / (count * mb) as f32;
    runner.update(inv_b);
    loss_sum
}

/// Borrow bundle for the depth-D scheduler: the engines, the network,
/// and the shared FA decode buffer. Ring state (the rounds slice plus
/// head/live indices) is threaded through the methods explicitly so
/// callers keep ownership of the scratch.
struct Overlap<'a, T: Transport> {
    runner: &'a mut EngineRunner,
    agg: &'a mut AggClient<T>,
    fa: &'a mut Vec<f32>,
    loss: Loss,
    lr: f32,
    stats: &'a mut PipelineStats,
}

impl<T: Transport> Overlap<'_, T> {
    /// Credit every finished backward to its round (non-blocking).
    fn reap(&mut self, rounds: &mut [PendingRound]) {
        while let Some((gslot, loss)) = self.runner.try_reap_backward() {
            rounds[gslot].loss_sum += loss;
            rounds[gslot].done += 1;
        }
    }

    /// Keep the engines busy without blocking: reap finished backwards,
    /// then dispatch ready FAs — oldest round first, so the head (the
    /// next to retire) drains soonest — while ring capacity lasts.
    fn feed(&mut self, rounds: &mut [PendingRound], head: usize, live: usize) {
        self.reap(rounds);
        let depth = rounds.len();
        for k in 0..live {
            let slot = (head + k) % depth;
            while self.runner.can_dispatch_backward() {
                let Some((idx, payload)) = rounds[slot].ready.pop() else { break };
                decode_activations_into(&payload, self.fa);
                self.runner.dispatch_backward(slot, idx, self.fa, self.lr, self.loss);
                self.stats.overlapped_backwards += 1;
            }
            if !self.runner.can_dispatch_backward() {
                return;
            }
        }
    }

    /// One scheduling step: feed the engines, then poll the transport
    /// once with `budget`, parking an arriving FA on whichever live
    /// round is waiting on its seq (and handing it straight to the
    /// engines when the ring has room). Returns `false` when the budget
    /// expired without an event.
    fn pump(&mut self, rounds: &mut [PendingRound], head: usize, live: usize, budget: Duration) -> bool {
        self.feed(rounds, head, live);
        let Some(ev) = self.agg.poll(budget) else { return false };
        let Event::Fa { seq, payload } = ev else { return true };
        let depth = rounds.len();
        for k in 0..live {
            let slot = (head + k) % depth;
            if let Some(pos) = rounds[slot].pending.iter().position(|(s, _)| *s == seq) {
                let (_, idx) = rounds[slot].pending.swap_remove(pos);
                rounds[slot].ready.push((idx, payload));
                if k > 0 {
                    // An FA for a round behind the retirement head —
                    // work the synchronous schedule would have forced
                    // before this round's forwards even ran.
                    self.stats.deferred_fas += 1;
                }
                self.feed(rounds, head, live);
                return true;
            }
        }
        // An FA for no live round is a client-level duplicate the
        // AggClient already filtered as far as it could; drop it.
        true
    }

    /// Retire the head round: drain its remaining FAs (the engines
    /// overlapping the drain), join its backwards, then apply its
    /// deferred update. Returns the round's loss, or `None` when a
    /// generation bump killed the round mid-drain (the caller must
    /// abort the whole ring — its FAs will never arrive).
    fn retire_head(&mut self, rounds: &mut [PendingRound], head: usize, live: usize) -> Option<f32> {
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while rounds[head].done < rounds[head].count {
            if self.agg.interrupted() {
                return None;
            }
            if rounds[head].pending.is_empty() {
                // Every head FA is in hand: run the engines dry. If the
                // head's remaining work sits in the engine ring
                // (possibly queued behind other rounds' jobs, or the
                // ring is full and its ready FAs can't enter), block on
                // the oldest outstanding job instead of spinning.
                self.feed(rounds, head, live);
                if rounds[head].done >= rounds[head].count {
                    break;
                }
                if self.runner.outstanding_backwards() > 0 {
                    let (gslot, loss) = self.runner.join_backward();
                    rounds[gslot].loss_sum += loss;
                    rounds[gslot].done += 1;
                }
                continue;
            }
            if !self.pump(rounds, head, live, Duration::from_millis(2)) {
                assert!(
                    Instant::now() < deadline,
                    "drain timeout: worker {} round [{}, {}) missing {} of {} backwards; \
                     pending seqs {:?}; in_flight {}; stats {:?}",
                    self.agg.worker(),
                    rounds[head].first,
                    rounds[head].first + rounds[head].count,
                    rounds[head].count - rounds[head].done,
                    rounds[head].count,
                    rounds[head].pending.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
                    self.agg.in_flight(),
                    self.agg.stats,
                );
            }
        }
        self.runner.update_slot(head, rounds[head].inv_b);
        self.stats.deferred_rounds += 1;
        let loss = rounds[head].loss_sum;
        rounds[head].retire();
        Some(loss)
    }
}

/// The depth-D schedule: round *k*'s forwards and PA sends run while up
/// to D-1 older rounds' backwards drain off the network and through the
/// engine ring; the *oldest* round retires (update applied, slot freed)
/// only when the ring is full, and round *k* is left in flight for
/// later calls (or [`flush_round`]) to retire.
#[allow(clippy::too_many_arguments)]
fn run_overlapped<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    let mb = runner.prep().mb;
    let depth = scratch.depth;
    let PipelineScratch { pa, payload, fa, rounds, head, live, .. } = scratch;
    pa.resize(mb, 0.0);
    let (mut head_i, mut live_i) = (*head, *live);
    // Begin round k in the next free ring slot (== its gradient slot).
    let tail = (head_i + live_i) % depth;
    rounds[tail].begin(first, count, 1.0 / (count * mb) as f32);
    live_i += 1;
    // This round's forwards read a model live-1 updates behind the
    // synchronous schedule — the bounded-staleness observation.
    stats.depth.observe_round(live_i - 1, live_i);
    let mut ctx = Overlap { runner, agg, fa, loss, lr, stats };

    // Stage 1: forward + ship round k; older rounds' backwards run on
    // the engines whenever the network hands us their FAs.
    for j in 0..count {
        let idx = first + j;
        if ctx.agg.interrupted() {
            abort_ring(ctx.runner, rounds);
            (*head, *live) = (0, 0);
            return 0.0;
        }
        ctx.feed(rounds, head_i, live_i);
        ctx.runner.forward(idx, pa);
        encode_activations_into(pa, payload);
        let seq = loop {
            if ctx.agg.interrupted() {
                abort_ring(ctx.runner, rounds);
                (*head, *live) = (0, 0);
                return 0.0;
            }
            if let Some(seq) = ctx.agg.try_send_pa(payload) {
                break seq;
            }
            // Window full: pump until an operation retires.
            ctx.pump(rounds, head_i, live_i, Duration::from_micros(200));
        };
        rounds[tail].pending.push((seq, idx));
        // Opportunistic drain: overlap communication with later forwards.
        while ctx.pump(rounds, head_i, live_i, Duration::ZERO) {}
    }

    // Stage 2: if the ring is now full, retire the oldest round — its
    // backwards had up to D-1 rounds of forwards and sends to hide
    // behind — so the next call finds a free slot.
    let retired = if live_i == depth {
        match ctx.retire_head(rounds, head_i, live_i) {
            Some(l) => {
                head_i = (head_i + 1) % depth;
                live_i -= 1;
                l
            }
            None => {
                // A membership change killed the drain: no deferred
                // backward crosses it — the whole ring resets.
                abort_ring(ctx.runner, rounds);
                (*head, *live) = (0, 0);
                return 0.0;
            }
        }
    } else {
        0.0
    };

    // Stage 3: start on whatever FAs are already in hand without
    // blocking; stragglers — and any still-queued backwards — are the
    // next call's (or the flush's) first order of business.
    while ctx.pump(rounds, head_i, live_i, Duration::ZERO) {}
    ctx.feed(rounds, head_i, live_i);
    if ctx.agg.interrupted() {
        abort_ring(ctx.runner, rounds);
        (*head, *live) = (0, 0);
        return 0.0;
    }

    (*head, *live) = (head_i, live_i);
    retired
}

/// Retire every in-flight round of the overlapped pipeline, oldest
/// first: drain their remaining FAs, join their backwards, apply their
/// deferred updates in round order, and return their summed loss (0.0
/// when nothing is in flight — depth 1, a fresh scratch, or an
/// already-flushed pipeline). Call at every point where the model must
/// be consistent with the rounds issued so far: epoch boundaries (exact
/// loss attribution, no cross-epoch staleness) and before exporting the
/// model.
pub fn flush_round<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    if scratch.live == 0 {
        return 0.0;
    }
    let retrans_mark = agg.stats.retransmits;
    let depth = scratch.depth;
    let PipelineScratch { fa, rounds, head, live, .. } = scratch;
    let mut total = 0.0f32;
    let mut ctx = Overlap { runner, agg, fa, loss, lr, stats };
    while *live > 0 {
        match ctx.retire_head(rounds, *head, *live) {
            Some(l) => {
                total += l;
                *head = (*head + 1) % depth;
                *live -= 1;
            }
            None => {
                // Generation bump mid-flush: the remaining rounds died
                // with the old membership — drain the ring cleanly and
                // let the trainer's interrupt check take over.
                abort_ring(ctx.runner, rounds);
                (*head, *live) = (0, 0);
                break;
            }
        }
    }
    let retrans_delta = ctx.agg.stats.retransmits - retrans_mark;
    ctx.stats.net.observe_round(retrans_delta);
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::shard_vertical;
    use crate::data::synth;
    use crate::engine::{Compute, NativeCompute};

    fn shard(d: usize, n: usize) -> VerticalShard {
        let ds = synth::separable(n, d, Loss::LogReg, 0.0, 11);
        shard_vertical(&ds, 1, 0, LANE)
    }

    #[test]
    fn prepare_shapes() {
        let prep = PreparedShard::prepare(&shard(100, 64), 4, 8, 4);
        assert_eq!(prep.engines.len(), 4);
        assert_eq!(prep.micro_batches(), 8);
        let total: usize = prep.engines.iter().map(|s| s.hi - s.lo).sum();
        assert_eq!(total, 100);
        for m in &prep.micro {
            assert_eq!(m.per_engine.len(), 4);
            assert_eq!(m.y.len(), 8);
        }
    }

    #[test]
    fn engine_sum_equals_whole_forward() {
        // splitting a worker over engines must not change PA
        let sh = shard(96, 16);
        let prep1 = PreparedShard::prepare(&sh, 1, 8, 4);
        let prep4 = PreparedShard::prepare(&sh, 3, 8, 4);
        let mut c = NativeCompute;
        let x_full: Vec<f32> = (0..96).map(|j| (j as f32 * 0.37).sin()).collect();

        // state with x = slices of x_full
        let mk_state = |prep: &PreparedShard| WorkerState {
            x: prep
                .engines
                .iter()
                .map(|s| {
                    let mut xe = vec![0.0f32; s.d_pad];
                    xe[..s.hi - s.lo].copy_from_slice(&x_full[s.lo..s.hi]);
                    xe
                })
                .collect(),
            g: prep.engines.iter().map(|s| vec![0.0f32; s.d_pad]).collect(),
        };
        let s1 = mk_state(&prep1);
        let s4 = mk_state(&prep4);
        for idx in 0..prep1.micro_batches() {
            let pa1: Vec<f32> = {
                let m = &prep1.micro[idx];
                let mut pa = vec![0.0f32; 8];
                for (ed, xe) in m.per_engine.iter().zip(&s1.x) {
                    for (p, v) in pa.iter_mut().zip(c.forward(ed, xe)) {
                        *p += v;
                    }
                }
                pa
            };
            let pa4: Vec<f32> = {
                let m = &prep4.micro[idx];
                let mut pa = vec![0.0f32; 8];
                for (ed, xe) in m.per_engine.iter().zip(&s4.x) {
                    for (p, v) in pa.iter_mut().zip(c.forward(ed, xe)) {
                        *p += v;
                    }
                }
                pa
            };
            for (a, b) in pa1.iter().zip(&pa4) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_depth_is_fixed_and_validated() {
        assert_eq!(PipelineScratch::new().depth(), 1);
        assert_eq!(PipelineScratch::default().depth(), 1);
        assert_eq!(PipelineScratch::with_depth(2).depth(), 2);
        let deep = PipelineScratch::with_depth(8);
        assert_eq!(deep.depth(), 8);
        assert_eq!(deep.rounds.len(), 8);
        assert_eq!(deep.in_flight_rounds(), 0);
    }

    #[test]
    #[should_panic(expected = "pipeline depth")]
    fn scratch_rejects_depth_out_of_range() {
        let _ = PipelineScratch::with_depth(9);
    }

    #[test]
    #[should_panic(expected = "pipeline depth")]
    fn scratch_rejects_depth_zero() {
        let _ = PipelineScratch::with_depth(0);
    }

    #[test]
    fn model_stitches_without_padding() {
        let prep = PreparedShard::prepare(&shard(100, 16), 4, 8, 4);
        let state = WorkerState::zeros(&prep);
        assert_eq!(state.model(&prep).len(), 100);
    }

    #[test]
    fn tiny_shard_fewer_engines_than_requested() {
        let prep = PreparedShard::prepare(&shard(3, 8), 8, 8, 4);
        assert!(prep.engines.len() <= 3);
    }
}
