//! Forward–Communication–Backward micro-batch pipeline (paper C2,
//! Fig. 2c) plus the data preparation it runs over.
//!
//! A mini-batch of `B` samples is split into `B/MB` micro-batches. The
//! worker issues forward passes back-to-back; each finished micro-batch's
//! PA is sent to the switch immediately (non-blocking slot claim), and
//! full activations are drained opportunistically between forwards, so
//! communication of micro-batch *j* overlaps the forward of *j+1..* and
//! the backward of earlier micro-batches — while gradient accumulation
//! keeps synchronous-SGD semantics (the model updates only at the
//! mini-batch boundary, after every FA arrived).
//!
//! **Zero-allocation steady state (§Perf L1):** everything the loop
//! touches per micro-batch is preallocated. [`PreparedShard`] holds the
//! bit-plane image only (the backward replays planes — no dequantized
//! copy, an ~8x memory cut at P=4); [`PipelineScratch`] carries the PA
//! accumulator, wire encode/decode buffers, and the seq→micro-batch
//! map; `AggClient` recycles payload buffers through an `Arc` pool.
//! After one warm-up mini-batch, [`run_minibatch`] performs **zero heap
//! allocations** per micro-batch on the native backend (enforced by
//! `tests/alloc_steady_state.rs` with a counting allocator).
//!
//! **Engine execution (§Perf L2):** per-engine compute state — model
//! and gradient slices, one `Compute` per engine, the per-engine
//! forward buffer — lives in the [`EngineRunner`], not here. The
//! pipeline drives it through three calls per micro-batch lifecycle:
//! `forward` (PA = ordered engine fan-in), `backward` (plane replay
//! against the decoded FA, gradients accumulated engine-locally), and
//! `update` at the mini-batch boundary. With `engine_threads > 1` those
//! calls dispatch to the runner's persistent thread pool over
//! preallocated Condvar/epoch job slots (see `engine::runner`), so
//! engine parallelism costs no steady-state allocation and changes no
//! numerics (ordered fan-in keeps f32 sums bit-identical).
//!
//! **Round overlap (§Perf L3, `pipeline_depth`):** at depth 1 (the
//! default) rounds are synchronous: [`run_minibatch`] forwards, drains
//! every FA (running backwards as they land), updates, and returns —
//! bit-compatible with the pre-overlap pipeline. At depth 2 the
//! backward+update of round *k* is deferred into round *k+1*'s call:
//! after round *k+1*'s forward fan-ins and PA sends, the worker
//! dispatches round *k*'s backwards to the engine pool **without
//! joining** ([`EngineRunner::dispatch_backward`]) and keeps polling
//! the transport while the engines run — the paper's
//! forward–communication–backward overlap, where aggregation latency
//! hides behind compute instead of serializing after it. A
//! `PendingRound` slot in [`PipelineScratch`] carries the in-flight
//! round between calls: its seq→micro-batch map, the FAs that arrived
//! before their gradient window opened (payload refcounts, decoded at
//! dispatch), its accumulated loss, and its deferred update scale.
//! The contract is **bounded staleness**: a round's forwards read the
//! model one update older than the synchronous schedule would, and
//! [`flush_round`] (called at every epoch boundary) retires the tail so
//! staleness never crosses an epoch and per-epoch loss attribution
//! stays exact. Gradient windows never mix: a round's backwards are
//! dispatched only after the previous round's update has been applied.

use crate::data::partition::{vertical, VerticalShard};
use crate::data::quantize::{pack_rows, PackedBatch, LANE};
use crate::engine::EngineRunner;
use crate::glm::Loss;
use crate::metrics::RoundNetStats;
use crate::net::Transport;
use crate::protocol::{decode_activations_into, encode_activations_into};
use crate::worker::{AggClient, Event};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Hard cap on waiting for stragglers before declaring the cluster dead.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// One engine's slice of the worker's model partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSlice {
    /// Offsets within the worker partition.
    pub lo: usize,
    pub hi: usize,
    /// Lane-padded width (engine datapath / artifact width).
    pub d_pad: usize,
}

/// One prepared micro-batch: per-engine bit-planes (forward *and*
/// plane-replay backward) plus labels.
#[derive(Debug, Clone)]
pub struct PreparedMicro {
    pub per_engine: Vec<PackedBatch>,
    pub y: Vec<f32>,
}

/// A worker's shard, quantized and packed once up front — the software
/// twin of the FPGA's bit-weaved HBM image.
#[derive(Debug, Clone)]
pub struct PreparedShard {
    pub engines: Vec<EngineSlice>,
    pub micro: Vec<PreparedMicro>,
    pub mb: usize,
    pub n: usize,
}

impl PreparedShard {
    /// Quantize + pack `shard` for `n_engines` engines at micro-batch
    /// size `mb` and the given bit-weaving precision.
    ///
    /// Engine slices are padded straight to the AOT artifact widths
    /// (256/1024/4096) when they fit: padding is inert for both
    /// backends (zero words), and it makes the PJRT path zero-copy
    /// (§Perf L1 — no per-call re-padding).
    pub fn prepare(shard: &VerticalShard, n_engines: usize, mb: usize, precision: u32) -> Self {
        let width = shard.slice.width();
        let n_engines = n_engines.min(width); // degenerate tiny shards
        let artifact_pad = |lane_pad: usize| -> usize {
            for v in [256usize, 1024, 4096] {
                if lane_pad <= v {
                    return v;
                }
            }
            lane_pad
        };
        let slices: Vec<EngineSlice> = vertical(width, n_engines, LANE)
            .into_iter()
            .map(|s| EngineSlice { lo: s.lo, hi: s.hi, d_pad: artifact_pad(s.padded) })
            .collect();
        let n_micro = shard.n / mb;
        let mut micro = Vec::with_capacity(n_micro);
        let mut scratch = Vec::new();
        for m in 0..n_micro {
            let rows = shard.rows(m * mb, (m + 1) * mb);
            let mut per_engine = Vec::with_capacity(slices.len());
            for s in &slices {
                let ew = s.hi - s.lo;
                scratch.clear();
                for i in 0..mb {
                    scratch.extend_from_slice(&rows[i * width + s.lo..i * width + s.hi]);
                }
                per_engine.push(pack_rows(&scratch, mb, ew, s.d_pad, precision));
            }
            micro.push(PreparedMicro {
                per_engine,
                y: shard.labels[m * mb..(m + 1) * mb].to_vec(),
            });
        }
        PreparedShard { engines: slices, micro, mb, n: shard.n }
    }

    pub fn micro_batches(&self) -> usize {
        self.micro.len()
    }
}

/// Mutable training state of one worker: per-engine model and gradient.
/// Owned by the [`EngineRunner`] during training (serial mode keeps it
/// whole; pool mode moves each engine's slices onto that engine's
/// thread); used directly only by the reference oracle and tests.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub x: Vec<Vec<f32>>,
    pub g: Vec<Vec<f32>>,
}

impl WorkerState {
    pub fn zeros(prep: &PreparedShard) -> Self {
        let x = prep.engines.iter().map(|s| vec![0.0f32; s.d_pad]).collect::<Vec<_>>();
        let g = x.clone();
        Self { x, g }
    }

    /// Stitch the (unpadded) model partition back together.
    pub fn model(&self, prep: &PreparedShard) -> Vec<f32> {
        let mut out = Vec::new();
        for (s, xe) in prep.engines.iter().zip(&self.x) {
            out.extend_from_slice(&xe[..s.hi - s.lo]);
        }
        out
    }
}

/// Counters from one mini-batch run.
#[derive(Debug, Default, Clone, Copy)]
pub struct PipelineStats {
    /// Micro-batches whose FA arrived only in the final drain (no
    /// overlap left to exploit). Depth-1 path only.
    pub drained: u64,
    /// Micro-batches overlapped with later forwards. Depth-1 path only.
    pub overlapped: u64,
    /// Depth-2: backward jobs dispatched to the engines while the
    /// dispatcher kept pumping the transport (the dispatch/join split).
    pub overlapped_backwards: u64,
    /// Depth-2: FAs parked because their round's gradient window wasn't
    /// open yet (backward deferred past the previous round's update).
    pub deferred_fas: u64,
    /// Depth-2: rounds retired through the deferred update path
    /// (including the flush at epoch boundaries).
    pub deferred_rounds: u64,
    /// Per-round network health, sampled once per round from cumulative
    /// `AggStats` deltas — never per packet (see [`RoundNetStats`]).
    pub net: RoundNetStats,
}

impl PipelineStats {
    /// Fold another worker's counters into this one.
    pub fn merge(&mut self, other: &Self) {
        self.drained += other.drained;
        self.overlapped += other.overlapped;
        self.overlapped_backwards += other.overlapped_backwards;
        self.deferred_fas += other.deferred_fas;
        self.deferred_rounds += other.deferred_rounds;
        self.net.merge(&other.net);
    }
}

/// One mini-batch round carried across [`run_minibatch`] calls by the
/// depth-2 pipeline: its aggregation traffic is still in flight while
/// the next round's forwards run. All buffers are reused round over
/// round, so the overlapped path stays allocation-free in steady state.
#[derive(Debug, Default)]
struct PendingRound {
    active: bool,
    /// Micro-batch range `[first, first + count)`.
    first: usize,
    count: usize,
    /// Deferred update scale, applied when the round retires.
    inv_b: f32,
    /// Loss accumulated from joined backwards.
    loss_sum: f32,
    /// Backwards fully executed (dispatched and joined).
    done: usize,
    /// seq -> micro-batch index, FAs still in flight.
    pending: Vec<(u16, usize)>,
    /// Arrived FAs awaiting the engines (payload refcounts; decoded at
    /// dispatch): either the engines are busy with an earlier
    /// micro-batch, or this round's gradient window hasn't opened yet.
    ready: Vec<(usize, Arc<[i32]>)>,
}

impl PendingRound {
    fn begin(&mut self, first: usize, count: usize, inv_b: f32) {
        debug_assert!(!self.active, "round slot still in flight");
        self.active = true;
        self.first = first;
        self.count = count;
        self.inv_b = inv_b;
        self.loss_sum = 0.0;
        self.done = 0;
        self.pending.clear();
        self.pending.reserve(count);
        self.ready.clear();
        self.ready.reserve(count);
    }

    fn retire(&mut self) {
        debug_assert!(self.done == self.count && self.pending.is_empty() && self.ready.is_empty());
        self.active = false;
    }
}

/// Reusable buffers for [`run_minibatch`]. Construct once per worker;
/// every capacity is established during the first mini-batch, after
/// which the steady-state loop never allocates. The scratch also fixes
/// the pipeline depth for its worker (the round slots it carries are
/// meaningless across a depth change).
#[derive(Debug)]
pub struct PipelineScratch {
    /// Engine-summed partial activations (MB wide).
    pa: Vec<f32>,
    /// Fixed-point wire payload (MB wide).
    payload: Vec<i32>,
    /// Decoded full activations (MB wide).
    fa: Vec<f32>,
    /// In-flight seq -> micro-batch index (≤ window entries; linear scan
    /// beats hashing at this size and never rehashes/allocates).
    /// Depth-1 path only — depth 2 tracks seqs per round.
    pending: Vec<(u16, usize)>,
    /// Overlap depth: 1 = synchronous rounds (bit-compatible with the
    /// pre-overlap pipeline), 2 = one round of
    /// forward–communication–backward overlap.
    depth: usize,
    /// Depth-2 round slots: one is the in-flight round, the other is
    /// recycled for the round being assembled.
    rounds: [PendingRound; 2],
    /// Which of `rounds` is the in-flight round.
    flip: bool,
}

impl Default for PipelineScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl PipelineScratch {
    /// Synchronous (depth-1) scratch — the bit-compatible schedule.
    pub fn new() -> Self {
        Self::with_depth(1)
    }

    /// `depth` ∈ {1, 2}: 1 runs rounds synchronously, 2 overlaps the
    /// backward+update of round *k* with round *k+1*'s forwards and
    /// sends (one-round staleness; see the module docs).
    pub fn with_depth(depth: usize) -> Self {
        assert!((1..=2).contains(&depth), "pipeline depth must be 1 or 2, got {depth}");
        Self {
            pa: Vec::new(),
            payload: Vec::new(),
            fa: Vec::new(),
            pending: Vec::new(),
            depth,
            rounds: [PendingRound::default(), PendingRound::default()],
            flip: false,
        }
    }

    /// The overlap depth this scratch drives.
    pub fn depth(&self) -> usize {
        self.depth
    }
}

/// Apply one FA event: decode, then loss + plane-replay backward on the
/// runner (fanned out across engine threads when the pool is active).
#[allow(clippy::too_many_arguments)]
fn on_event(
    ev: Event,
    runner: &mut EngineRunner,
    pending: &mut Vec<(u16, usize)>,
    fa_buf: &mut Vec<f32>,
    loss: Loss,
    lr: f32,
    loss_sum: &mut f32,
    done: &mut usize,
) {
    let Event::Fa { seq, payload } = ev else { return };
    let Some(pos) = pending.iter().position(|(s, _)| *s == seq) else { return };
    let (_, idx) = pending.swap_remove(pos);
    decode_activations_into(&payload, fa_buf);
    *loss_sum += runner.backward(idx, fa_buf, lr, loss);
    *done += 1;
}

/// Run one mini-batch (micro-batches `[first, first + count)`) through
/// the FCB pipeline. Returns the summed training loss of the mini-batch
/// at depth 1; at depth 2 it returns the loss of the round *retired*
/// this call (the previous one — 0.0 on the first call of an epoch),
/// and [`flush_round`] returns the tail.
///
/// At depth 1 the runner enters with zeroed gradients (fresh from
/// construction or from the previous `update`, which clears them) and
/// leaves the same way — gradient state never leaks across
/// mini-batches. At depth 2 the call leaves one round in flight in the
/// scratch; its gradients retire on the next call or at the flush.
#[allow(clippy::too_many_arguments)]
pub fn run_minibatch<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    // Per-round network health: one cumulative-counter delta per round,
    // not a sample per packet (noise-free under loss).
    let retrans_mark = agg.stats.retransmits;
    let loss_out = if scratch.depth >= 2 {
        run_overlapped(runner, agg, first, count, loss, lr, stats, scratch)
    } else {
        run_synchronous(runner, agg, first, count, loss, lr, stats, scratch)
    };
    stats.net.observe_round(agg.stats.retransmits - retrans_mark);
    loss_out
}

/// The depth-1 schedule: forward + ship every micro-batch, drain every
/// FA (backwards run as they land), update, return. Bit-compatible with
/// the pre-overlap pipeline.
#[allow(clippy::too_many_arguments)]
fn run_synchronous<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    let mb = runner.prep().mb;
    let PipelineScratch { pa, payload, fa, pending, .. } = scratch;
    pa.resize(mb, 0.0);
    // `fa` and `payload` size themselves inside the into-codecs (clear +
    // extend), so their capacity is warm after the first micro-batch.
    pending.clear();
    pending.reserve(count);
    let mut loss_sum = 0.0f32;
    let mut done = 0usize;

    // Stage 1+2 interleaved: forward each micro-batch, ship PA, drain FAs.
    for j in 0..count {
        let idx = first + j;
        // Forward across engines; PA is the engine-sum (paper §4.1.3),
        // fanned in over engine outputs in engine order.
        runner.forward(idx, pa);
        encode_activations_into(pa, payload);
        // Claim a slot; pump the network while backpressured.
        let seq = loop {
            if let Some(seq) = agg.try_send_pa(payload) {
                break seq;
            }
            if let Some(ev) = agg.poll(Duration::from_micros(200)) {
                on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
            }
        };
        pending.push((seq, idx));
        // Opportunistic drain: overlap communication with later forwards.
        while let Some(ev) = agg.poll(Duration::ZERO) {
            let before = done;
            on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
            if done > before && j + 1 < count {
                stats.overlapped += 1;
            }
        }
    }

    // Stage 3 tail: block for the remaining FAs.
    let deadline = std::time::Instant::now() + DRAIN_TIMEOUT;
    while done < count {
        let Some(ev) = agg.poll(Duration::from_millis(20)) else {
            assert!(
                std::time::Instant::now() < deadline,
                "drain timeout: worker {} missing {} of {count} micro-batches; \
                 pending seqs {:?}; in_flight {}; stats {:?}",
                agg.worker(),
                count - done,
                pending.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
                agg.in_flight(),
                agg.stats,
            );
            continue;
        };
        let before = done;
        on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
        if done > before {
            stats.drained += 1;
        }
    }

    // Model update at the mini-batch boundary (synchronous SGD
    // preserved); the runner zeroes its gradients for the next window.
    let inv_b = 1.0 / (count * mb) as f32;
    runner.update(inv_b);
    loss_sum
}

/// Borrow bundle for the depth-2 scheduler: the engines, the network,
/// and the shared FA decode buffer.
struct Overlap<'a, T: Transport> {
    runner: &'a mut EngineRunner,
    agg: &'a mut AggClient<T>,
    fa: &'a mut Vec<f32>,
    loss: Loss,
    lr: f32,
    stats: &'a mut PipelineStats,
}

impl<T: Transport> Overlap<'_, T> {
    /// Block until the open backward (if any) finishes, crediting `r` —
    /// the round that owns the current gradient window.
    fn join_open(&mut self, r: &mut PendingRound) {
        if self.runner.backward_open() {
            r.loss_sum += self.runner.join_backward();
            r.done += 1;
        }
    }

    /// Keep the engines busy without blocking: reap a finished backward
    /// and dispatch the next ready FA of `r`. No-op while a backward is
    /// still running (the dispatcher goes back to polling instead).
    fn feed_engines(&mut self, r: &mut PendingRound) {
        if !r.active {
            return;
        }
        if self.runner.backward_open() {
            if !self.runner.backward_done() {
                return;
            }
            r.loss_sum += self.runner.join_backward();
            r.done += 1;
        }
        if let Some((idx, payload)) = r.ready.pop() {
            decode_activations_into(&payload, self.fa);
            self.runner.dispatch_backward(idx, self.fa, self.lr, self.loss);
            self.stats.overlapped_backwards += 1;
        }
    }

    /// One scheduling step: feed the engines from `owner` (the round
    /// whose gradient window is open), then poll the transport once
    /// with `budget`. An arriving FA is parked on whichever round is
    /// waiting on its seq: `owner`'s FAs become engine work
    /// immediately, `parked`'s wait for the window to open. Returns
    /// `false` when the budget expired without an event.
    fn pump(&mut self, owner: &mut PendingRound, parked: &mut PendingRound, budget: Duration) -> bool {
        self.feed_engines(owner);
        let Some(ev) = self.agg.poll(budget) else { return false };
        let Event::Fa { seq, payload } = ev else { return true };
        if let Some(pos) = owner.pending.iter().position(|(s, _)| *s == seq) {
            let (_, idx) = owner.pending.swap_remove(pos);
            owner.ready.push((idx, payload));
            self.feed_engines(owner);
        } else if let Some(pos) = parked.pending.iter().position(|(s, _)| *s == seq) {
            let (_, idx) = parked.pending.swap_remove(pos);
            parked.ready.push((idx, payload));
            self.stats.deferred_fas += 1;
        }
        // An FA for neither round is a client-level duplicate the
        // AggClient already filtered as far as it could; drop it.
        true
    }

    /// Retire `r`: drain its remaining FAs (the engines overlapping the
    /// drain), join every backward, then apply the deferred update.
    /// Returns the round's loss.
    fn retire(&mut self, r: &mut PendingRound, parked: &mut PendingRound) -> f32 {
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while r.done < r.count {
            if r.pending.is_empty() {
                // Every FA is in hand: run the engines dry.
                self.feed_engines(r);
                self.join_open(r);
                continue;
            }
            if !self.pump(r, parked, Duration::from_millis(2)) {
                assert!(
                    Instant::now() < deadline,
                    "drain timeout: worker {} round [{}, {}) missing {} of {} backwards; \
                     pending seqs {:?}; in_flight {}; stats {:?}",
                    self.agg.worker(),
                    r.first,
                    r.first + r.count,
                    r.count - r.done,
                    r.count,
                    r.pending.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
                    self.agg.in_flight(),
                    self.agg.stats,
                );
            }
        }
        self.runner.update(r.inv_b);
        self.stats.deferred_rounds += 1;
        let loss = r.loss_sum;
        r.retire();
        loss
    }
}

/// The depth-2 schedule: round *k*'s forwards and PA sends run while
/// round *k-1*'s backwards drain off the network and through the engine
/// pool; round *k-1*'s update applies mid-call, and round *k* is left
/// in flight for the next call (or [`flush_round`]) to retire.
#[allow(clippy::too_many_arguments)]
fn run_overlapped<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    let mb = runner.prep().mb;
    let PipelineScratch { pa, payload, fa, rounds, flip, .. } = scratch;
    pa.resize(mb, 0.0);
    let [r0, r1] = rounds;
    let (prev, cur) = if *flip { (r1, r0) } else { (r0, r1) };
    cur.begin(first, count, 1.0 / (count * mb) as f32);
    let mut ctx = Overlap { runner, agg, fa, loss, lr, stats };

    // Stage 1: forward + ship round k; round k-1's backwards run on the
    // engines whenever the network hands us their FAs.
    for j in 0..count {
        let idx = first + j;
        // The runner executes one job class at a time: reap the open
        // backward (round k-1's) before dispatching a forward.
        ctx.join_open(prev);
        ctx.runner.forward(idx, pa);
        encode_activations_into(pa, payload);
        let seq = loop {
            if let Some(seq) = ctx.agg.try_send_pa(payload) {
                break seq;
            }
            // Window full: pump until an operation retires.
            ctx.pump(prev, cur, Duration::from_micros(200));
        };
        cur.pending.push((seq, idx));
        // Opportunistic drain: overlap communication with later forwards.
        while ctx.pump(prev, cur, Duration::ZERO) {}
    }

    // Stage 2: retire round k-1 — the rest of its backwards, then its
    // deferred update. Round k's early FAs park on `cur` meanwhile.
    let retired = if prev.active { ctx.retire(prev, cur) } else { 0.0 };

    // Stage 3: the gradient window now belongs to round k; start on its
    // already-arrived FAs without blocking. Stragglers — and the open
    // backward we may leave behind — are the next call's (or the
    // flush's) first order of business.
    while ctx.pump(cur, prev, Duration::ZERO) {}
    ctx.feed_engines(cur);

    *flip = !*flip;
    retired
}

/// Retire the depth-2 pipeline's in-flight round, if any: drain its
/// remaining FAs, join its backwards, apply its deferred update, and
/// return its loss (0.0 when nothing is pending — depth 1, a fresh
/// scratch, or an already-flushed pipeline). Call at every point where
/// the model must be consistent with the rounds issued so far: epoch
/// boundaries (exact loss attribution, no cross-epoch staleness) and
/// before exporting the model.
pub fn flush_round<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    let retrans_mark = agg.stats.retransmits;
    let PipelineScratch { fa, rounds, flip, .. } = scratch;
    let [r0, r1] = rounds;
    // After a run_minibatch call the in-flight round sits where the
    // *next* call would look for its previous round.
    let (prev, cur) = if *flip { (r1, r0) } else { (r0, r1) };
    debug_assert!(!cur.active, "assembly slot must be idle between calls");
    if !prev.active {
        return 0.0;
    }
    let mut ctx = Overlap { runner, agg, fa, loss, lr, stats };
    let retired = ctx.retire(prev, cur);
    stats.net.observe_round(agg.stats.retransmits - retrans_mark);
    retired
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::shard_vertical;
    use crate::data::synth;
    use crate::engine::{Compute, NativeCompute};

    fn shard(d: usize, n: usize) -> VerticalShard {
        let ds = synth::separable(n, d, Loss::LogReg, 0.0, 11);
        shard_vertical(&ds, 1, 0, LANE)
    }

    #[test]
    fn prepare_shapes() {
        let prep = PreparedShard::prepare(&shard(100, 64), 4, 8, 4);
        assert_eq!(prep.engines.len(), 4);
        assert_eq!(prep.micro_batches(), 8);
        let total: usize = prep.engines.iter().map(|s| s.hi - s.lo).sum();
        assert_eq!(total, 100);
        for m in &prep.micro {
            assert_eq!(m.per_engine.len(), 4);
            assert_eq!(m.y.len(), 8);
        }
    }

    #[test]
    fn engine_sum_equals_whole_forward() {
        // splitting a worker over engines must not change PA
        let sh = shard(96, 16);
        let prep1 = PreparedShard::prepare(&sh, 1, 8, 4);
        let prep4 = PreparedShard::prepare(&sh, 3, 8, 4);
        let mut c = NativeCompute;
        let x_full: Vec<f32> = (0..96).map(|j| (j as f32 * 0.37).sin()).collect();

        // state with x = slices of x_full
        let mk_state = |prep: &PreparedShard| WorkerState {
            x: prep
                .engines
                .iter()
                .map(|s| {
                    let mut xe = vec![0.0f32; s.d_pad];
                    xe[..s.hi - s.lo].copy_from_slice(&x_full[s.lo..s.hi]);
                    xe
                })
                .collect(),
            g: prep.engines.iter().map(|s| vec![0.0f32; s.d_pad]).collect(),
        };
        let s1 = mk_state(&prep1);
        let s4 = mk_state(&prep4);
        for idx in 0..prep1.micro_batches() {
            let pa1: Vec<f32> = {
                let m = &prep1.micro[idx];
                let mut pa = vec![0.0f32; 8];
                for (ed, xe) in m.per_engine.iter().zip(&s1.x) {
                    for (p, v) in pa.iter_mut().zip(c.forward(ed, xe)) {
                        *p += v;
                    }
                }
                pa
            };
            let pa4: Vec<f32> = {
                let m = &prep4.micro[idx];
                let mut pa = vec![0.0f32; 8];
                for (ed, xe) in m.per_engine.iter().zip(&s4.x) {
                    for (p, v) in pa.iter_mut().zip(c.forward(ed, xe)) {
                        *p += v;
                    }
                }
                pa
            };
            for (a, b) in pa1.iter().zip(&pa4) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_depth_is_fixed_and_validated() {
        assert_eq!(PipelineScratch::new().depth(), 1);
        assert_eq!(PipelineScratch::default().depth(), 1);
        assert_eq!(PipelineScratch::with_depth(2).depth(), 2);
    }

    #[test]
    #[should_panic(expected = "pipeline depth")]
    fn scratch_rejects_depth_out_of_range() {
        let _ = PipelineScratch::with_depth(3);
    }

    #[test]
    fn model_stitches_without_padding() {
        let prep = PreparedShard::prepare(&shard(100, 16), 4, 8, 4);
        let state = WorkerState::zeros(&prep);
        assert_eq!(state.model(&prep).len(), 100);
    }

    #[test]
    fn tiny_shard_fewer_engines_than_requested() {
        let prep = PreparedShard::prepare(&shard(3, 8), 8, 8, 4);
        assert!(prep.engines.len() <= 3);
    }
}
