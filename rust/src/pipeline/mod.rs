//! Forward–Communication–Backward micro-batch pipeline (paper C2,
//! Fig. 2c) plus the data preparation it runs over.
//!
//! A mini-batch of `B` samples is split into `B/MB` micro-batches. The
//! worker issues forward passes back-to-back; each finished micro-batch's
//! PA is sent to the switch immediately (non-blocking slot claim), and
//! full activations are drained opportunistically between forwards, so
//! communication of micro-batch *j* overlaps the forward of *j+1..* and
//! the backward of earlier micro-batches — while gradient accumulation
//! keeps synchronous-SGD semantics (the model updates only at the
//! mini-batch boundary, after every FA arrived).
//!
//! **Zero-allocation steady state (§Perf L1):** everything the loop
//! touches per micro-batch is preallocated. [`PreparedShard`] holds the
//! bit-plane image only (the backward replays planes — no dequantized
//! copy, an ~8x memory cut at P=4); [`PipelineScratch`] carries the PA
//! accumulator, wire encode/decode buffers, and the seq→micro-batch
//! map; `AggClient` recycles payload buffers through an `Arc` pool.
//! After one warm-up mini-batch, [`run_minibatch`] performs **zero heap
//! allocations** per micro-batch on the native backend (enforced by
//! `tests/alloc_steady_state.rs` with a counting allocator).
//!
//! **Engine execution (§Perf L2):** per-engine compute state — model
//! and gradient slices, one `Compute` per engine, the per-engine
//! forward buffer — lives in the [`EngineRunner`], not here. The
//! pipeline drives it through three calls per micro-batch lifecycle:
//! `forward` (PA = ordered engine fan-in), `backward` (plane replay
//! against the decoded FA, gradients accumulated engine-locally), and
//! `update` at the mini-batch boundary. With `engine_threads > 1` those
//! calls dispatch to the runner's persistent thread pool over
//! preallocated Condvar/epoch job slots (see `engine::runner`), so
//! engine parallelism costs no steady-state allocation and changes no
//! numerics (ordered fan-in keeps f32 sums bit-identical).

use crate::data::partition::{vertical, VerticalShard};
use crate::data::quantize::{pack_rows, PackedBatch, LANE};
use crate::engine::EngineRunner;
use crate::glm::Loss;
use crate::net::Transport;
use crate::protocol::{decode_activations_into, encode_activations_into};
use crate::worker::{AggClient, Event};
use std::time::Duration;

/// Hard cap on waiting for stragglers before declaring the cluster dead.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// One engine's slice of the worker's model partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineSlice {
    /// Offsets within the worker partition.
    pub lo: usize,
    pub hi: usize,
    /// Lane-padded width (engine datapath / artifact width).
    pub d_pad: usize,
}

/// One prepared micro-batch: per-engine bit-planes (forward *and*
/// plane-replay backward) plus labels.
#[derive(Debug, Clone)]
pub struct PreparedMicro {
    pub per_engine: Vec<PackedBatch>,
    pub y: Vec<f32>,
}

/// A worker's shard, quantized and packed once up front — the software
/// twin of the FPGA's bit-weaved HBM image.
#[derive(Debug, Clone)]
pub struct PreparedShard {
    pub engines: Vec<EngineSlice>,
    pub micro: Vec<PreparedMicro>,
    pub mb: usize,
    pub n: usize,
}

impl PreparedShard {
    /// Quantize + pack `shard` for `n_engines` engines at micro-batch
    /// size `mb` and the given bit-weaving precision.
    ///
    /// Engine slices are padded straight to the AOT artifact widths
    /// (256/1024/4096) when they fit: padding is inert for both
    /// backends (zero words), and it makes the PJRT path zero-copy
    /// (§Perf L1 — no per-call re-padding).
    pub fn prepare(shard: &VerticalShard, n_engines: usize, mb: usize, precision: u32) -> Self {
        let width = shard.slice.width();
        let n_engines = n_engines.min(width); // degenerate tiny shards
        let artifact_pad = |lane_pad: usize| -> usize {
            for v in [256usize, 1024, 4096] {
                if lane_pad <= v {
                    return v;
                }
            }
            lane_pad
        };
        let slices: Vec<EngineSlice> = vertical(width, n_engines, LANE)
            .into_iter()
            .map(|s| EngineSlice { lo: s.lo, hi: s.hi, d_pad: artifact_pad(s.padded) })
            .collect();
        let n_micro = shard.n / mb;
        let mut micro = Vec::with_capacity(n_micro);
        let mut scratch = Vec::new();
        for m in 0..n_micro {
            let rows = shard.rows(m * mb, (m + 1) * mb);
            let mut per_engine = Vec::with_capacity(slices.len());
            for s in &slices {
                let ew = s.hi - s.lo;
                scratch.clear();
                for i in 0..mb {
                    scratch.extend_from_slice(&rows[i * width + s.lo..i * width + s.hi]);
                }
                per_engine.push(pack_rows(&scratch, mb, ew, s.d_pad, precision));
            }
            micro.push(PreparedMicro {
                per_engine,
                y: shard.labels[m * mb..(m + 1) * mb].to_vec(),
            });
        }
        PreparedShard { engines: slices, micro, mb, n: shard.n }
    }

    pub fn micro_batches(&self) -> usize {
        self.micro.len()
    }
}

/// Mutable training state of one worker: per-engine model and gradient.
/// Owned by the [`EngineRunner`] during training (serial mode keeps it
/// whole; pool mode moves each engine's slices onto that engine's
/// thread); used directly only by the reference oracle and tests.
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub x: Vec<Vec<f32>>,
    pub g: Vec<Vec<f32>>,
}

impl WorkerState {
    pub fn zeros(prep: &PreparedShard) -> Self {
        let x = prep.engines.iter().map(|s| vec![0.0f32; s.d_pad]).collect::<Vec<_>>();
        let g = x.clone();
        Self { x, g }
    }

    /// Stitch the (unpadded) model partition back together.
    pub fn model(&self, prep: &PreparedShard) -> Vec<f32> {
        let mut out = Vec::new();
        for (s, xe) in prep.engines.iter().zip(&self.x) {
            out.extend_from_slice(&xe[..s.hi - s.lo]);
        }
        out
    }
}

/// Counters from one mini-batch run.
#[derive(Debug, Default, Clone, Copy)]
pub struct PipelineStats {
    /// Micro-batches whose FA arrived only in the final drain (no
    /// overlap left to exploit).
    pub drained: u64,
    /// Micro-batches overlapped with later forwards.
    pub overlapped: u64,
}

/// Reusable buffers for [`run_minibatch`]. Construct once per worker;
/// every capacity is established during the first mini-batch, after
/// which the steady-state loop never allocates.
#[derive(Debug, Default)]
pub struct PipelineScratch {
    /// Engine-summed partial activations (MB wide).
    pa: Vec<f32>,
    /// Fixed-point wire payload (MB wide).
    payload: Vec<i32>,
    /// Decoded full activations (MB wide).
    fa: Vec<f32>,
    /// In-flight seq -> micro-batch index (≤ window entries; linear scan
    /// beats hashing at this size and never rehashes/allocates).
    pending: Vec<(u16, usize)>,
}

impl PipelineScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Apply one FA event: decode, then loss + plane-replay backward on the
/// runner (fanned out across engine threads when the pool is active).
#[allow(clippy::too_many_arguments)]
fn on_event(
    ev: Event,
    runner: &mut EngineRunner,
    pending: &mut Vec<(u16, usize)>,
    fa_buf: &mut Vec<f32>,
    loss: Loss,
    lr: f32,
    loss_sum: &mut f32,
    done: &mut usize,
) {
    let Event::Fa { seq, payload } = ev else { return };
    let Some(pos) = pending.iter().position(|(s, _)| *s == seq) else { return };
    let (_, idx) = pending.swap_remove(pos);
    decode_activations_into(&payload, fa_buf);
    *loss_sum += runner.backward(idx, fa_buf, lr, loss);
    *done += 1;
}

/// Run one mini-batch (micro-batches `[first, first + count)`) through
/// the FCB pipeline. Returns the summed training loss of the mini-batch.
///
/// The runner enters with zeroed gradients (fresh from construction or
/// from the previous `update`, which clears them) and leaves the same
/// way — gradient state never leaks across mini-batches.
#[allow(clippy::too_many_arguments)]
pub fn run_minibatch<T: Transport>(
    runner: &mut EngineRunner,
    agg: &mut AggClient<T>,
    first: usize,
    count: usize,
    loss: Loss,
    lr: f32,
    stats: &mut PipelineStats,
    scratch: &mut PipelineScratch,
) -> f32 {
    let mb = runner.prep().mb;
    let PipelineScratch { pa, payload, fa, pending } = scratch;
    pa.resize(mb, 0.0);
    // `fa` and `payload` size themselves inside the into-codecs (clear +
    // extend), so their capacity is warm after the first micro-batch.
    pending.clear();
    pending.reserve(count);
    let mut loss_sum = 0.0f32;
    let mut done = 0usize;

    // Stage 1+2 interleaved: forward each micro-batch, ship PA, drain FAs.
    for j in 0..count {
        let idx = first + j;
        // Forward across engines; PA is the engine-sum (paper §4.1.3),
        // fanned in over engine outputs in engine order.
        runner.forward(idx, pa);
        encode_activations_into(pa, payload);
        // Claim a slot; pump the network while backpressured.
        let seq = loop {
            if let Some(seq) = agg.try_send_pa(payload) {
                break seq;
            }
            if let Some(ev) = agg.poll(Duration::from_micros(200)) {
                on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
            }
        };
        pending.push((seq, idx));
        // Opportunistic drain: overlap communication with later forwards.
        while let Some(ev) = agg.poll(Duration::ZERO) {
            let before = done;
            on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
            if done > before && j + 1 < count {
                stats.overlapped += 1;
            }
        }
    }

    // Stage 3 tail: block for the remaining FAs.
    let deadline = std::time::Instant::now() + DRAIN_TIMEOUT;
    while done < count {
        let Some(ev) = agg.poll(Duration::from_millis(20)) else {
            assert!(
                std::time::Instant::now() < deadline,
                "drain timeout: worker {} missing {} of {count} micro-batches; \
                 pending seqs {:?}; in_flight {}; stats {:?}",
                agg.worker(),
                count - done,
                pending.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
                agg.in_flight(),
                agg.stats,
            );
            continue;
        };
        let before = done;
        on_event(ev, runner, pending, fa, loss, lr, &mut loss_sum, &mut done);
        if done > before {
            stats.drained += 1;
        }
    }

    // Model update at the mini-batch boundary (synchronous SGD
    // preserved); the runner zeroes its gradients for the next window.
    let inv_b = 1.0 / (count * mb) as f32;
    runner.update(inv_b);
    loss_sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::shard_vertical;
    use crate::data::synth;
    use crate::engine::{Compute, NativeCompute};

    fn shard(d: usize, n: usize) -> VerticalShard {
        let ds = synth::separable(n, d, Loss::LogReg, 0.0, 11);
        shard_vertical(&ds, 1, 0, LANE)
    }

    #[test]
    fn prepare_shapes() {
        let prep = PreparedShard::prepare(&shard(100, 64), 4, 8, 4);
        assert_eq!(prep.engines.len(), 4);
        assert_eq!(prep.micro_batches(), 8);
        let total: usize = prep.engines.iter().map(|s| s.hi - s.lo).sum();
        assert_eq!(total, 100);
        for m in &prep.micro {
            assert_eq!(m.per_engine.len(), 4);
            assert_eq!(m.y.len(), 8);
        }
    }

    #[test]
    fn engine_sum_equals_whole_forward() {
        // splitting a worker over engines must not change PA
        let sh = shard(96, 16);
        let prep1 = PreparedShard::prepare(&sh, 1, 8, 4);
        let prep4 = PreparedShard::prepare(&sh, 3, 8, 4);
        let mut c = NativeCompute;
        let x_full: Vec<f32> = (0..96).map(|j| (j as f32 * 0.37).sin()).collect();

        // state with x = slices of x_full
        let mk_state = |prep: &PreparedShard| WorkerState {
            x: prep
                .engines
                .iter()
                .map(|s| {
                    let mut xe = vec![0.0f32; s.d_pad];
                    xe[..s.hi - s.lo].copy_from_slice(&x_full[s.lo..s.hi]);
                    xe
                })
                .collect(),
            g: prep.engines.iter().map(|s| vec![0.0f32; s.d_pad]).collect(),
        };
        let s1 = mk_state(&prep1);
        let s4 = mk_state(&prep4);
        for idx in 0..prep1.micro_batches() {
            let pa1: Vec<f32> = {
                let m = &prep1.micro[idx];
                let mut pa = vec![0.0f32; 8];
                for (ed, xe) in m.per_engine.iter().zip(&s1.x) {
                    for (p, v) in pa.iter_mut().zip(c.forward(ed, xe)) {
                        *p += v;
                    }
                }
                pa
            };
            let pa4: Vec<f32> = {
                let m = &prep4.micro[idx];
                let mut pa = vec![0.0f32; 8];
                for (ed, xe) in m.per_engine.iter().zip(&s4.x) {
                    for (p, v) in pa.iter_mut().zip(c.forward(ed, xe)) {
                        *p += v;
                    }
                }
                pa
            };
            for (a, b) in pa1.iter().zip(&pa4) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn model_stitches_without_padding() {
        let prep = PreparedShard::prepare(&shard(100, 16), 4, 8, 4);
        let state = WorkerState::zeros(&prep);
        assert_eq!(state.model(&prep).len(), 100);
    }

    #[test]
    fn tiny_shard_fewer_engines_than_requested() {
        let prep = PreparedShard::prepare(&shard(3, 8), 8, 8, 4);
        assert!(prep.engines.len() <= 3);
    }
}
