//! Content-addressed checkpoint distribution.
//!
//! A replica fleet converges on the newest model without a
//! coordinator, the way build-distribution systems ship artifacts:
//! immutable blobs named by their own hash, plus one tiny mutable
//! pointer.
//!
//! # Store layout
//!
//! ```text
//! store/
//!   objects/ab/cd/abcd567890123456   # checkpoint bytes, named by
//!                                    # their FNV-1a-64 hex digest,
//!                                    # two-level fan-out on the first
//!                                    # four digits
//!   LATEST                           # "<digest> <epoch>\n"
//! ```
//!
//! The fan-out keeps directories small when every training epoch
//! publishes. Objects are **immutable**: a digest names exactly one
//! byte string, so re-publishing identical content is a no-op, a
//! partially fetched object is detected by re-hashing, and nothing
//! ever needs invalidation. `LATEST` is the only thing that moves, and
//! it moves by atomic rename — a reader sees the old pointer or the
//! new one, never a torn line.
//!
//! # Publish ordering
//!
//! [`publish`] writes the object (tmp + rename + dir fsync) **before**
//! swinging `LATEST`, so a pointer never references an object that is
//! not yet durable. [`Fetcher::poll`] still re-hashes every fetched
//! object and retries briefly: on a shared filesystem the object may
//! lag the pointer, and a digest mismatch must read as "not yet",
//! never as a served model.

use crate::checkpoint::{fnv1a, Checkpoint};
use anyhow::{bail, Context, Result};
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// The digest's hex form used in object names and `LATEST`.
fn digest(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a(bytes))
}

/// Object path for a digest: two-level fan-out on the first four hex
/// digits (256 × 256 dirs), then the full digest as the file name.
fn object_path(store: &Path, digest: &str) -> PathBuf {
    store.join("objects").join(&digest[0..2]).join(&digest[2..4]).join(digest)
}

/// Write `bytes` to `path` atomically: temp file in the same
/// directory, fsync, rename, fsync the directory.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().context("atomic write target has no parent")?;
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let tmp = dir.join(format!(
        ".{}.tmp-{}",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("obj"),
        std::process::id()
    ));
    let mut f = fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    fs::rename(&tmp, path).with_context(|| format!("publishing {}", path.display()))?;
    if let Ok(d) = fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Publish a checkpoint into the store: object first, pointer second.
/// Returns the digest. Idempotent — identical content republished is
/// one `stat` plus the pointer swing.
pub fn publish(store: &Path, ck: &Checkpoint) -> Result<String> {
    let bytes = ck.to_bytes();
    let d = digest(&bytes);
    let obj = object_path(store, &d);
    // Content-addressed: if the object exists it *is* this content
    // (modulo a torn publish, which the fetch-side re-hash catches and
    // a re-publish here repairs).
    let fresh = match fs::metadata(&obj) {
        Ok(m) if m.len() == bytes.len() as u64 => false,
        _ => true,
    };
    if fresh {
        write_atomic(&obj, &bytes)?;
    }
    write_atomic(&store.join("LATEST"), format!("{d} {}\n", ck.epoch).as_bytes())?;
    Ok(d)
}

/// Parse a `LATEST` line into `(digest, epoch)`.
fn parse_latest(text: &str) -> Option<(String, usize)> {
    let mut it = text.split_whitespace();
    let d = it.next()?;
    if d.len() != 16 || !d.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    let epoch = it.next()?.parse().ok()?;
    Some((d.to_string(), epoch))
}

/// Fetch the object `digest` names, verifying the content actually
/// hashes to it. `Err` here means "retry", not "corrupt store": on a
/// shared filesystem the bytes may simply not all be visible yet.
fn fetch_object(store: &Path, d: &str) -> Result<Vec<u8>> {
    let path = object_path(store, d);
    let bytes = fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
    if digest(&bytes) != d {
        bail!("object {d} failed its digest check ({} bytes) — torn or lagging", bytes.len());
    }
    Ok(bytes)
}

/// How many times [`Fetcher::poll`] retries a digest-mismatched or
/// missing object before giving up until the next poll.
const FETCH_RETRIES: u32 = 3;

/// An incremental store reader for the serve loop: remembers the last
/// digest it delivered and answers `Ok(None)` from a single small read
/// of `LATEST` when nothing moved — the store-side twin of
/// [`checkpoint::Watcher`](crate::checkpoint::Watcher).
#[derive(Debug)]
pub struct Fetcher {
    store: PathBuf,
    delivered: Option<String>,
}

impl Fetcher {
    /// Read from `store` (which may not exist yet).
    pub fn new(store: impl Into<PathBuf>) -> Self {
        Self { store: store.into(), delivered: None }
    }

    /// The digest of the last checkpoint this fetcher delivered.
    pub fn delivered(&self) -> Option<&str> {
        self.delivered.as_deref()
    }

    /// Re-check the store. `Ok(Some)` is a newly fetched, digest- and
    /// checksum-verified checkpoint; `Ok(None)` means the pointer has
    /// not moved (or the store does not exist yet, or the new object
    /// is still lagging the pointer — both resolve on a later poll).
    pub fn poll(&mut self) -> Result<Option<Checkpoint>> {
        let text = match fs::read_to_string(self.store.join("LATEST")) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e).context("reading LATEST"),
        };
        let Some((d, _epoch)) = parse_latest(&text) else {
            // Unparseable pointer: atomic renames should make this
            // impossible, so stay quiet and let the next publish fix it.
            return Ok(None);
        };
        if self.delivered.as_deref() == Some(d.as_str()) {
            return Ok(None);
        }
        let mut last_err = None;
        for attempt in 0..FETCH_RETRIES {
            if attempt > 0 {
                std::thread::sleep(std::time::Duration::from_millis(2 << attempt));
            }
            match fetch_object(&self.store, &d).and_then(|b| Checkpoint::from_bytes(&b)) {
                Ok(ck) => {
                    self.delivered = Some(d);
                    return Ok(Some(ck));
                }
                Err(e) => last_err = Some(e),
            }
        }
        // Exhausted: treat as "not yet" — the pointer stays undelivered
        // so the next poll retries from scratch.
        let _ = last_err;
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(epoch: usize) -> Checkpoint {
        Checkpoint {
            generation: 1,
            epoch,
            rounds_done: epoch as u64,
            rng: 7,
            model: vec![0.5, -1.25, 3.0],
            loss_curve: vec![1.0],
        }
    }

    fn tmpstore(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("p4sgd-dist-test-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn publish_fetch_roundtrip_and_fanout_layout() {
        let store = tmpstore("roundtrip");
        let d = publish(&store, &sample(3)).unwrap();
        assert_eq!(d.len(), 16);
        // Two-level fan-out: objects/ab/cd/<digest>.
        let obj = store.join("objects").join(&d[0..2]).join(&d[2..4]).join(&d);
        assert!(obj.is_file(), "missing {}", obj.display());
        let mut f = Fetcher::new(&store);
        let ck = f.poll().unwrap().expect("published checkpoint fetched");
        assert_eq!(ck.epoch, 3);
        assert_eq!(f.delivered(), Some(d.as_str()));
        assert!(f.poll().unwrap().is_none(), "unchanged pointer is quiet");
        let _ = fs::remove_dir_all(&store);
    }

    #[test]
    fn fetcher_follows_pointer_moves_and_is_idempotent() {
        let store = tmpstore("moves");
        let mut f = Fetcher::new(&store);
        assert!(f.poll().unwrap().is_none(), "missing store is quiet");
        publish(&store, &sample(1)).unwrap();
        assert_eq!(f.poll().unwrap().unwrap().epoch, 1);
        let d2a = publish(&store, &sample(2)).unwrap();
        let d2b = publish(&store, &sample(2)).unwrap();
        assert_eq!(d2a, d2b, "identical content has one digest");
        assert_eq!(f.poll().unwrap().unwrap().epoch, 2);
        assert!(f.poll().unwrap().is_none(), "re-publish of same content is quiet");
        let _ = fs::remove_dir_all(&store);
    }

    #[test]
    fn torn_object_is_not_served() {
        let store = tmpstore("torn");
        let d = publish(&store, &sample(4)).unwrap();
        // Simulate a lagging/torn object behind a fresh pointer.
        let obj = object_path(&store, &d);
        let bytes = fs::read(&obj).unwrap();
        fs::write(&obj, &bytes[..bytes.len() / 2]).unwrap();
        let mut f = Fetcher::new(&store);
        assert!(f.poll().unwrap().is_none(), "digest mismatch must read as not-yet");
        assert_eq!(f.delivered(), None);
        // Repair (re-publish) and the same fetcher recovers.
        publish(&store, &sample(4)).unwrap();
        assert_eq!(f.poll().unwrap().unwrap().epoch, 4);
        let _ = fs::remove_dir_all(&store);
    }

    #[test]
    fn latest_pointer_format_is_strict() {
        assert_eq!(parse_latest("0123456789abcdef 7\n"), Some(("0123456789abcdef".into(), 7)));
        assert_eq!(parse_latest("xyz 7"), None, "non-hex digest");
        assert_eq!(parse_latest("0123456789abcdef"), None, "missing epoch");
        assert_eq!(parse_latest(""), None);
    }
}
