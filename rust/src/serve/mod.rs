//! The model-serving tier: a hot-swap checkpoint inference server.
//!
//! Training produces round-consistent checkpoints; this module is the
//! path from those checkpoints to predictions. A serve replica
//! (`--role serve`, `[serve]` config) is built from four pieces:
//!
//! * **Model publication** — the newest valid checkpoint is loaded
//!   (via [`checkpoint::Watcher`] on the checkpoint directory, or
//!   fetched from a content-addressed [`dist`] store) and published as
//!   an [`Arc<Model>`] behind a [`ModelCell`]. A newer checkpoint is
//!   installed with one pointer swap: readers that already cloned the
//!   `Arc` finish their batch on the old model, new batches pick up
//!   the new one. No pause, no torn state — a reader sees the old
//!   model or the new model, never a mixture.
//! * **Admission batching** — requests are queued per shard and
//!   flushed when `max_batch` rows are waiting or the oldest has
//!   waited `max_wait_us`. Batching amortizes the pack + forward cost
//!   exactly the way small-batch training amortizes aggregation
//!   latency (the paper's premise, mirrored on the serve side).
//! * **Shared-nothing shards** — each shard owns a pinned thread
//!   ([`util::affinity`]), its own queues and scratch buffers (NUMA
//!   first-touch on the shard's core), and shares *nothing* mutable
//!   with other shards; requests are dispatched by `req_id % shards`.
//!   The forward is the training kernel itself ([`pack_rows`] +
//!   [`forward_into`]), so served scores are **bitwise identical** to
//!   the training-side forward on the same model and rows.
//! * **Wire protocol** — requests/responses are the v1 frames of
//!   [`protocol::serve`], carried by the same kernel-UDP stack as
//!   training traffic.
//!
//! [`checkpoint::Watcher`]: crate::checkpoint::Watcher
//! [`util::affinity`]: crate::util::affinity
//! [`pack_rows`]: crate::data::quantize::pack_rows
//! [`forward_into`]: crate::engine::bitserial::forward_into
//! [`protocol::serve`]: crate::protocol::serve

pub mod dist;
pub mod load;
pub mod shard;

use crate::checkpoint::{Checkpoint, Watcher};
use crate::config::SystemConfig;
use crate::data::quantize::LANE;
use crate::metrics::ServeStats;
use crate::net::{serve_node, udp, NodeId, Transport};
use crate::protocol::{serve as wire, Ctrl};
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

/// An immutable, ready-to-score model: checkpoint weights padded to
/// the pack lane width once at load time, so the per-batch path does
/// no copying or padding.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    /// Epoch of the checkpoint this model came from (reported in every
    /// response's `gen` field — the observable hot-swap tests key on).
    pub epoch: u32,
    /// Membership generation recorded at checkpoint time.
    pub generation: u32,
    /// Feature count requests must match exactly.
    pub d_in: usize,
    /// `d_in` rounded up to a [`LANE`] multiple: the packed width.
    pub d_pad: usize,
    /// Weights, zero-padded from `d_in` to `d_pad`.
    pub weights: Vec<f32>,
}

impl Model {
    /// Build a servable model from a checkpoint.
    pub fn from_checkpoint(ck: &Checkpoint) -> Self {
        let d_in = ck.model.len();
        let d_pad = d_in.div_ceil(LANE) * LANE;
        let mut weights = Vec::with_capacity(d_pad);
        weights.extend_from_slice(&ck.model);
        weights.resize(d_pad, 0.0);
        Self { epoch: ck.epoch as u32, generation: ck.generation, d_in, d_pad, weights }
    }
}

/// The hot-swap publication point: one cell, many reader threads.
///
/// `load` is a read-lock held only long enough to clone the `Arc` (one
/// refcount bump — no weight bytes are copied); `swap` is a write-lock
/// store of a new pointer. With respect to readers the swap is atomic:
/// a `load` returns the complete old model or the complete new one,
/// never a mixture, and in-flight batches that already hold an `Arc`
/// keep scoring on the model they started with. Readers are never
/// blocked for longer than the pointer store itself — there is no
/// drain, no pause.
#[derive(Debug, Default)]
pub struct ModelCell {
    inner: RwLock<Option<Arc<Model>>>,
}

impl ModelCell {
    /// An empty cell: the server can start before the first checkpoint
    /// exists and reject requests until one lands.
    pub fn empty() -> Self {
        Self::default()
    }

    /// A cell pre-loaded with `model`.
    pub fn new(model: Model) -> Self {
        Self { inner: RwLock::new(Some(Arc::new(model))) }
    }

    /// The currently published model (`None` until the first publish).
    pub fn load(&self) -> Option<Arc<Model>> {
        self.inner.read().expect("model cell poisoned").clone()
    }

    /// Publish `model`, returning the epoch it replaced.
    pub fn swap(&self, model: Arc<Model>) -> Option<u32> {
        let mut slot = self.inner.write().expect("model cell poisoned");
        let old = slot.as_ref().map(|m| m.epoch);
        *slot = Some(model);
        old
    }
}

/// Where a replica discovers new models: a checkpoint directory
/// watched by name/mtime high-water mark, or a content-addressed
/// distribution store probed by its `LATEST` pointer.
enum Source {
    Dir(Watcher),
    Store(dist::Fetcher),
}

impl Source {
    fn poll(&mut self) -> Result<Option<Checkpoint>> {
        match self {
            Source::Dir(w) => w.poll(),
            Source::Store(f) => f.poll(),
        }
    }
}

/// How many switch nodes the training plan occupies (the serve node
/// plan starts after them; see [`serve_node`]).
pub fn switch_count(cfg: &SystemConfig) -> usize {
    if cfg.switch.tree {
        cfg.switch.leaves + 1
    } else {
        1
    }
}

/// The node id replica `replica` binds under `cfg`'s port plan.
pub fn replica_node(cfg: &SystemConfig, replica: usize) -> NodeId {
    serve_node(cfg.cluster.workers, switch_count(cfg), replica)
}

/// Run one serve replica until a `Ctrl::Leave` frame arrives (the
/// graceful-shutdown signal — the cluster teardown and the loadgen's
/// `--stop-server` both send it). Returns the merged serve counters.
pub fn run(cfg: &SystemConfig, replica: usize) -> Result<ServeStats> {
    let node = replica_node(cfg, replica);
    let ep = udp::bind_one(node, cfg.cluster.base_port)
        .with_context(|| format!("binding serve node {node} (stale process on the port?)"))?;
    let mut source = match &cfg.serve.store {
        Some(store) => Source::Store(dist::Fetcher::new(store)),
        None => {
            let dir = cfg
                .cluster
                .checkpoint_dir
                .as_ref()
                .context("serve role needs cluster.checkpoint_dir or serve.store")?;
            Source::Dir(Watcher::new(dir))
        }
    };
    let cell = Arc::new(ModelCell::empty());
    if let Some(ck) = source.poll()? {
        let m = Model::from_checkpoint(&ck);
        eprintln!("[serve {replica}] loaded model epoch {} (d={})", m.epoch, m.d_in);
        cell.swap(Arc::new(m));
    } else {
        eprintln!("[serve {replica}] no checkpoint yet; rejecting until one lands");
    }
    let stats = serve_loop(cfg, ep, cell, &mut source, replica)?;
    eprintln!("[serve {replica}] {}", stats.summary());
    Ok(stats)
}

/// The socket-owning event loop: dispatch requests to shards, flush
/// shard responses back to the wire, and poll the model source on the
/// configured cadence. Separated from [`run`] so tests can drive it
/// with a pre-seeded cell.
fn serve_loop(
    cfg: &SystemConfig,
    mut ep: udp::UdpEndpoint,
    cell: Arc<ModelCell>,
    source: &mut Source,
    replica: usize,
) -> Result<ServeStats> {
    let n_shards = cfg.serve.shards;
    let (resp_tx, resp_rx) = mpsc::channel::<shard::Response>();
    let mut shards = Vec::with_capacity(n_shards);
    for s in 0..n_shards {
        let core = cfg.cluster.core_offset + replica * n_shards + s;
        shards.push(shard::spawn(
            s,
            core,
            cfg.serve.clone(),
            cfg.train.precision,
            cfg.cluster.numa_local,
            Arc::clone(&cell),
            resp_tx.clone(),
        ));
    }
    drop(resp_tx); // shards hold the only senders: channel closes with them
    let poll_every = Duration::from_millis(cfg.serve.poll_ms);
    // A short recv budget keeps response flushing prompt without
    // spinning: the worst case it adds to a response's latency is one
    // budget.
    let recv_budget = Duration::from_micros(200);
    let mut last_poll = Instant::now();
    loop {
        if let Some((src, pkt)) = ep.recv_timeout(recv_budget) {
            match pkt.ctrl {
                Ctrl::ServeReq => {
                    let id = wire::req_id(&pkt);
                    let s = id as usize % n_shards;
                    shards[s].dispatch(shard::Request { id, src, pkt });
                }
                Ctrl::Leave => break,
                _ => {} // training traffic astray on the serve port: drop
            }
        }
        for resp in resp_rx.try_iter() {
            ep.send(resp.src, &resp.pkt);
        }
        if last_poll.elapsed() >= poll_every {
            last_poll = Instant::now();
            if let Some(ck) = source.poll()? {
                let m = Arc::new(Model::from_checkpoint(&ck));
                let old = cell.swap(Arc::clone(&m));
                eprintln!(
                    "[serve {replica}] hot-swap: epoch {:?} -> {} (zero pause)",
                    old, m.epoch
                );
            }
        }
    }
    // Graceful drain: stop admitting, let every shard flush its queue,
    // then push the remaining responses out.
    let mut total = ServeStats::default();
    for sh in shards {
        total.merge(&sh.stop());
    }
    for resp in resp_rx.try_iter() {
        ep.send(resp.src, &resp.pkt);
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ck(epoch: usize, weights: Vec<f32>) -> Checkpoint {
        Checkpoint {
            generation: 1,
            epoch,
            rounds_done: 0,
            rng: 0,
            model: weights,
            loss_curve: Vec::new(),
        }
    }

    #[test]
    fn model_pads_to_lane_width() {
        let m = Model::from_checkpoint(&ck(3, vec![1.0; 33]));
        assert_eq!((m.d_in, m.d_pad), (33, 64));
        assert_eq!(m.weights.len(), 64);
        assert!(m.weights[33..].iter().all(|&w| w == 0.0));
        // Already-aligned widths must not grow.
        let m = Model::from_checkpoint(&ck(3, vec![1.0; 64]));
        assert_eq!((m.d_in, m.d_pad), (64, 64));
    }

    #[test]
    fn cell_swap_is_old_or_new_never_torn() {
        let cell = ModelCell::empty();
        assert!(cell.load().is_none());
        cell.swap(Arc::new(Model::from_checkpoint(&ck(1, vec![1.0; 8]))));
        let held = cell.load().expect("published");
        assert_eq!(held.epoch, 1);
        let replaced = cell.swap(Arc::new(Model::from_checkpoint(&ck(2, vec![2.0; 8]))));
        assert_eq!(replaced, Some(1));
        // The Arc held across the swap still sees the *complete* old
        // model — in-flight batches finish on what they started with.
        assert_eq!(held.epoch, 1);
        assert!(held.weights.iter().all(|&w| w == 1.0));
        assert_eq!(cell.load().unwrap().epoch, 2);
    }

    #[test]
    fn replica_nodes_sit_past_the_training_plan() {
        let mut cfg = SystemConfig::default();
        cfg.cluster.workers = 4;
        // flat: workers 0..4, switch 4, coordinator 5 -> replicas 6, 7
        assert_eq!(replica_node(&cfg, 0), 6);
        assert_eq!(replica_node(&cfg, 1), 7);
        cfg.switch.tree = true;
        cfg.switch.leaves = 2;
        // tree: leaves 4..6, spine 6, coordinator 7 -> replica 8
        assert_eq!(replica_node(&cfg, 0), 8);
    }
}
