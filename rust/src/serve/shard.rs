//! Shared-nothing serve shards: one pinned thread per shard, an
//! admission queue in front of it, and the training forward kernel
//! behind it.
//!
//! # Why shared-nothing
//!
//! The only thing two shards ever share is the read-only
//! [`ModelCell`] pointer. Queues, scratch rows, packed planes, and
//! stats accumulators are all shard-private and first-touched on the
//! shard's own core (so with `numa_local` they land in that socket's
//! memory). There are no locks on the request path — dispatch is
//! `req_id % shards` in the socket thread, and each shard drains its
//! own `mpsc` queue.
//!
//! # Admission batching
//!
//! A shard blocks until a first request arrives, then collects more
//! until either `max_batch` rows are waiting or the *first* request
//! has waited `max_wait_us`. The flushed batch is packed once
//! ([`pack_rows`]) and scored with one [`forward_into`] call — the
//! same kernel training uses, which is what makes served scores
//! bitwise identical to the training-side forward.
//!
//! # Hot-swap visibility
//!
//! The model pointer is loaded **once per flush**, so an entire batch
//! is scored by exactly one model and score changes land on a clean
//! batch boundary. Every response reports the epoch that scored it;
//! the hot-swap tests group responses by flush id and assert one epoch
//! per flush.

use super::{Model, ModelCell};
use crate::config::ServeConfig;
use crate::data::quantize::pack_rows;
use crate::engine::bitserial::forward_into;
use crate::metrics::ServeStats;
use crate::net::NodeId;
use crate::protocol::{serve as wire, Packet};
use crate::util::affinity;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A request as the socket thread hands it to a shard: the undecoded
/// frame plus its routing metadata. Decoding happens on the shard's
/// core so the socket thread stays a pure dispatcher.
pub struct Request {
    /// Request id (`protocol::serve::req_id`).
    pub id: u32,
    /// Node to answer to.
    pub src: NodeId,
    /// The `ServeReq` frame.
    pub pkt: Packet,
}

/// A scored (or rejected) response on its way back to the wire.
pub struct Response {
    /// Node to answer to.
    pub src: NodeId,
    /// The `ServeResp` frame.
    pub pkt: Packet,
    /// Shard-local flush counter: every response scored in the same
    /// batch carries the same value. Tests use it to assert that score
    /// changes land only on flush boundaries.
    pub flush: u64,
}

/// The pure compute core of a shard: pack one batch of rows and run
/// the training forward. Holds the scratch buffers so the steady state
/// allocates nothing; owns no threads, locks, or queues — unit tests
/// and the bitwise-identity test drive it directly.
pub struct ShardCore {
    precision: u32,
    rows: Vec<f32>,
    out: Vec<f32>,
}

impl ShardCore {
    pub fn new(precision: u32) -> Self {
        Self { precision, rows: Vec::new(), out: Vec::new() }
    }

    /// Score `batch` (rows of exactly `model.d_in` features) against
    /// `model`, returning one score per row. The result is bitwise
    /// identical to `forward_into(pack_rows(rows, mb, d_in, d_pad,
    /// precision), weights)` — it *is* that call.
    pub fn score_batch(&mut self, model: &Model, batch: &[Vec<f32>]) -> &[f32] {
        let mb = batch.len();
        self.rows.clear();
        for row in batch {
            debug_assert_eq!(row.len(), model.d_in);
            self.rows.extend_from_slice(row);
        }
        let pb = pack_rows(&self.rows, mb, model.d_in, model.d_pad, self.precision);
        self.out.clear();
        self.out.resize(mb, 0.0);
        forward_into(&pb, &model.weights, &mut self.out);
        &self.out
    }
}

/// A running shard: its admission queue and join handle.
pub struct ShardHandle {
    tx: SyncSender<Request>,
    join: JoinHandle<ServeStats>,
    /// Requests dropped because the admission queue was full
    /// (backpressure: better an explicit drop + client retry than an
    /// unbounded queue hiding overload).
    pub overflow: u64,
}

impl ShardHandle {
    /// Enqueue a request. A full queue drops the request — the client
    /// retransmits, exactly like any other lost datagram.
    pub fn dispatch(&mut self, req: Request) {
        match self.tx.try_send(req) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.overflow += 1;
            }
        }
    }

    /// Close the admission queue, let the shard drain what it already
    /// accepted, and return its counters.
    pub fn stop(self) -> ServeStats {
        drop(self.tx);
        self.join.join().unwrap_or_default()
    }
}

/// Spawn a shard thread: pin it to `core`, first-touch its buffers
/// there (NUMA-local when `numa_local`), and run the admission-batch
/// loop until the queue closes.
pub fn spawn(
    shard: usize,
    core: usize,
    cfg: ServeConfig,
    precision: u32,
    numa_local: bool,
    cell: Arc<ModelCell>,
    resp_tx: Sender<Response>,
) -> ShardHandle {
    // Bounded queue: several batches of headroom per shard.
    let depth = (cfg.max_batch * 8).max(64);
    let (tx, rx) = std::sync::mpsc::sync_channel::<Request>(depth);
    let join = std::thread::Builder::new()
        .name(format!("serve-shard-{shard}"))
        .spawn(move || {
            affinity::pin_current(core);
            run_loop(&cfg, precision, numa_local, &cell, &rx, &resp_tx)
        })
        .expect("spawning serve shard");
    ShardHandle { tx, join, overflow: 0 }
}

/// The shard loop body (separate from [`spawn`] so the hot-swap tests
/// can run it on their own threads and channels). Returns when the
/// request channel closes, after draining everything already queued.
pub fn run_loop(
    cfg: &ServeConfig,
    precision: u32,
    numa_local: bool,
    cell: &ModelCell,
    rx: &Receiver<Request>,
    resp_tx: &Sender<Response>,
) -> ServeStats {
    let mut core = ShardCore::new(precision);
    if numa_local {
        // First-touch the row scratch at a plausible batch size so the
        // pages fault in on this core's NUMA node before the hot loop.
        core.rows.resize(cfg.max_batch * 64, 0.0);
        affinity::bind_to_current_node(&core.rows);
        core.rows.clear();
    }
    let max_wait = Duration::from_micros(cfg.max_wait_us);
    let mut stats = ServeStats::default();
    let mut flush: u64 = 0;
    let mut prev_epoch: Option<u32> = None;
    let mut ids: Vec<u32> = Vec::with_capacity(cfg.max_batch);
    let mut srcs: Vec<NodeId> = Vec::with_capacity(cfg.max_batch);
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(cfg.max_batch);
    let mut rejects: Vec<(u32, NodeId)> = Vec::new();
    loop {
        // Admission: block for the first request, then top up until the
        // batch is full or the first row's deadline passes. The model
        // pointer is loaded once, at batch start — every row in this
        // flush scores on exactly that model.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break, // queue closed and drained: shutdown
        };
        let deadline = Instant::now() + max_wait;
        let model = cell.load();
        admit(&mut ids, &mut srcs, &mut rows, &mut rejects, first, model.as_deref());
        let mut full = rows.len() >= cfg.max_batch;
        while !full {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => {
                    admit(&mut ids, &mut srcs, &mut rows, &mut rejects, r, model.as_deref());
                    full = rows.len() >= cfg.max_batch;
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        if !rows.is_empty() {
            let m = model.as_ref().expect("rows are admitted only against a published model");
            let scores = core.score_batch(m, &rows);
            for ((&id, &src), &score) in ids.iter().zip(srcs.iter()).zip(scores.iter()) {
                let pkt = wire::response(id, m.epoch, score);
                let _ = resp_tx.send(Response { src, pkt, flush });
            }
            stats.served += rows.len() as u64;
            stats.batched_rows += rows.len() as u64;
            if full {
                stats.full_flushes += 1;
            } else {
                stats.timeout_flushes += 1;
            }
            if prev_epoch.replace(m.epoch).is_some_and(|p| p != m.epoch) {
                stats.swaps += 1;
            }
        }
        for (id, src) in rejects.drain(..) {
            let _ = resp_tx.send(Response { src, pkt: wire::reject(id), flush });
            stats.rejected += 1;
        }
        if !rows.is_empty() {
            flush += 1;
        }
        ids.clear();
        srcs.clear();
        rows.clear();
    }
    stats
}

/// Admit one request into the forming batch, or queue a rejection
/// (malformed frame, wrong feature width, or no model published yet).
fn admit(
    ids: &mut Vec<u32>,
    srcs: &mut Vec<NodeId>,
    rows: &mut Vec<Vec<f32>>,
    rejects: &mut Vec<(u32, NodeId)>,
    req: Request,
    model: Option<&Model>,
) {
    let mut row = Vec::new();
    let ok = wire::features_into(&req.pkt, &mut row);
    match model {
        Some(m) if ok && row.len() == m.d_in => {
            ids.push(req.id);
            srcs.push(req.src);
            rows.push(row);
        }
        _ => rejects.push((req.id, req.src)),
    }
}
