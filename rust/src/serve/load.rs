//! The serve-tier load generator: closed- and open-loop request
//! drivers over the real kernel-UDP stack, with a machine-readable
//! verdict for CI and the bench harness.
//!
//! * **Closed loop** measures capacity: `concurrency` client threads
//!   each keep exactly one request in flight (send, await, repeat), so
//!   sustained predictions/s is the server's actual service rate at
//!   that concurrency, and latency includes admission-batching wait.
//! * **Open loop** measures latency under a *fixed offered rate*: one
//!   paced sender that never slows down when the server does — the
//!   honest way to read p99/p999, since a closed loop hides queueing
//!   by backing off (coordinated omission).
//!
//! Feature rows are generated deterministically from `(seed, req_id)`,
//! so a verifier that knows the seed and the model can recompute every
//! expected score **bitwise** ([`expected_score`] uses the same
//! [`ShardCore`] path the server runs) without any side channel.

use super::shard::ShardCore;
use super::Model;
use crate::net::{udp, NodeId, Transport};
use crate::protocol::{serve as wire, Packet};
use crate::util::rng::Pcg32;
use crate::util::stats::Samples;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

/// One load run's shape.
#[derive(Debug, Clone)]
pub struct LoadCfg {
    /// The port plan shared with the server.
    pub base_port: u16,
    /// Server node id ([`super::replica_node`]).
    pub server: NodeId,
    /// First client node id; client `t` binds `client_base + t`. Must
    /// not collide with the server's plan.
    pub client_base: NodeId,
    /// Features per request row (must match the served model's `d_in`
    /// for scores; mismatched rows measure the rejection path).
    pub d: usize,
    /// Total requests to issue.
    pub requests: usize,
    /// Closed-loop client threads (ignored when `rate` is set).
    pub concurrency: usize,
    /// Open-loop offered rate, requests/s; `None` selects closed loop.
    pub rate: Option<f64>,
    /// Per-request retransmit timeout.
    pub timeout: Duration,
    /// Closed-loop retransmits before a request counts as lost.
    pub retries: u32,
    /// Row-generation seed.
    pub seed: u64,
}

impl Default for LoadCfg {
    fn default() -> Self {
        Self {
            base_port: 46000,
            server: 2,
            client_base: 3,
            d: 64,
            requests: 1000,
            concurrency: 4,
            rate: None,
            timeout: Duration::from_millis(100),
            retries: 20,
            seed: 1,
        }
    }
}

/// The measured outcome, in the shape `--report` serializes for CI.
#[derive(Debug, Clone, Default)]
pub struct Verdict {
    pub mode: &'static str,
    pub requests: usize,
    pub ok: usize,
    pub rejected: usize,
    pub lost: usize,
    pub elapsed_s: f64,
    pub predictions_per_s: f64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    /// Distinct model epochs observed in responses (hot-swap evidence).
    pub epochs_seen: Vec<u32>,
    /// Bitwise check against a local model: `None` = not requested,
    /// `Some(n)` = n scored responses checked, all exact.
    pub bitwise_checked: Option<usize>,
}

/// The deterministic feature row for request `id`: uniform in [-1, 1),
/// reproducible by any party holding the seed.
pub fn row_for(seed: u64, id: u32, d: usize) -> Vec<f32> {
    let mut rng = Pcg32::new(seed, id as u64);
    (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

/// The score the server must produce for request `id` — the same
/// [`ShardCore`] call the shard makes, so equality is bitwise, not
/// approximate.
pub fn expected_score(core: &mut ShardCore, model: &Model, seed: u64, id: u32) -> f32 {
    let row = row_for(seed, id, model.d_in);
    core.score_batch(model, std::slice::from_ref(&row))[0]
}

/// A scored response as the drivers collect them: `(request id, model
/// epoch, score)`.
pub type Scored = (u32, u32, f32);

/// Ask a server to shut down gracefully (it treats `Leave` as the
/// drain-and-exit signal).
pub fn stop_server(cfg: &LoadCfg) -> Result<()> {
    let mut ep = udp::bind_one(cfg.client_base, cfg.base_port).context("binding stop client")?;
    ep.send(cfg.server, &Packet::leave(0, 0));
    Ok(())
}

/// Run the configured load shape against a live server. Returns the
/// verdict plus every scored response, so the caller can feed them to
/// [`verify_bitwise`].
pub fn run(cfg: &LoadCfg) -> Result<(Verdict, Vec<Scored>)> {
    if cfg.rate.is_some() {
        open_loop(cfg)
    } else {
        closed_loop(cfg)
    }
}

/// Closed loop: `concurrency` threads, one request in flight each.
/// Thread `t` owns ids `t, t+concurrency, …` and its own socket, so
/// responses cannot cross threads (the server answers the asking
/// node).
fn closed_loop(cfg: &LoadCfg) -> Result<(Verdict, Vec<Scored>)> {
    let threads = cfg.concurrency.max(1);
    let started = Instant::now();
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let cfg = cfg.clone();
        let mut ep = udp::bind_one(cfg.client_base + t, cfg.base_port)
            .with_context(|| format!("binding loadgen client {t}"))?;
        handles.push(std::thread::spawn(move || {
            let mut lat: Vec<f64> = Vec::new();
            let mut scores: Vec<Scored> = Vec::new();
            let (mut ok, mut rejected, mut lost) = (0usize, 0usize, 0usize);
            let mut id = t as u32;
            while (id as usize) < cfg.requests {
                let row = row_for(cfg.seed, id, cfg.d);
                let req = wire::request(id, &row);
                let t0 = Instant::now();
                let mut done = false;
                'attempt: for _ in 0..=cfg.retries {
                    ep.send(cfg.server, &req);
                    let deadline = Instant::now() + cfg.timeout;
                    loop {
                        let now = Instant::now();
                        if now >= deadline {
                            break; // retransmit
                        }
                        let Some((_, pkt)) = ep.recv_timeout(deadline - now) else { continue };
                        if wire::req_id(&pkt) != id {
                            continue; // stale duplicate from a retransmit
                        }
                        if wire::is_reject(&pkt) {
                            rejected += 1;
                        } else if let Some((rid, epoch, score)) = wire::decode_response(&pkt) {
                            lat.push(t0.elapsed().as_secs_f64());
                            scores.push((rid, epoch, score));
                            ok += 1;
                        } else {
                            continue;
                        }
                        done = true;
                        break 'attempt;
                    }
                }
                if !done {
                    lost += 1;
                }
                id += threads as u32;
            }
            (lat, scores, ok, rejected, lost)
        }));
    }
    let mut lat = Samples::new();
    let mut scores = Vec::new();
    let (mut ok, mut rejected, mut lost) = (0, 0, 0);
    for h in handles {
        let (l, s, o, r, x) = h.join().expect("loadgen thread");
        for v in l {
            lat.push(v);
        }
        scores.extend(s);
        ok += o;
        rejected += r;
        lost += x;
    }
    let v = verdict("closed", cfg, started.elapsed(), lat, &scores, ok, rejected, lost);
    Ok((v, scores))
}

/// Open loop: one socket, sends paced at `rate`, receives
/// continuously. In-flight requests are tracked by id; anything not
/// answered `timeout` after the last send counts as lost.
fn open_loop(cfg: &LoadCfg) -> Result<(Verdict, Vec<Scored>)> {
    let rate = cfg.rate.expect("open_loop requires a rate");
    let gap = Duration::from_secs_f64(1.0 / rate.max(1.0));
    let mut ep =
        udp::bind_one(cfg.client_base, cfg.base_port).context("binding open-loop client")?;
    let mut outstanding: HashMap<u32, Instant> = HashMap::new();
    let mut lat = Samples::new();
    let mut scores: Vec<(u32, u32, f32)> = Vec::new();
    let (mut ok, mut rejected) = (0usize, 0usize);
    let started = Instant::now();
    let mut drain = |ep: &mut udp::UdpEndpoint,
                     outstanding: &mut HashMap<u32, Instant>,
                     budget: Duration| {
        let deadline = Instant::now() + budget;
        loop {
            let now = Instant::now();
            let left = deadline.checked_duration_since(now).unwrap_or(Duration::ZERO);
            let Some((_, pkt)) = ep.recv_timeout(left) else { break };
            let id = wire::req_id(&pkt);
            let Some(sent) = outstanding.remove(&id) else { continue };
            if wire::is_reject(&pkt) {
                rejected += 1;
            } else if let Some((rid, epoch, score)) = wire::decode_response(&pkt) {
                lat.push(sent.elapsed().as_secs_f64());
                scores.push((rid, epoch, score));
                ok += 1;
            }
            if left.is_zero() {
                break;
            }
        }
    };
    for id in 0..cfg.requests as u32 {
        // Pace against the *schedule*, not the previous send, so a slow
        // server cannot slow the offered rate (no coordinated omission).
        let due = started + gap.mul_f64(id as f64);
        let now = Instant::now();
        if now < due {
            drain(&mut ep, &mut outstanding, due - now);
        } else {
            drain(&mut ep, &mut outstanding, Duration::ZERO);
        }
        let row = row_for(cfg.seed, id, cfg.d);
        outstanding.insert(id, Instant::now());
        ep.send(cfg.server, &wire::request(id, &row));
    }
    drain(&mut ep, &mut outstanding, cfg.timeout);
    let lost = outstanding.len();
    let v = verdict("open", cfg, started.elapsed(), lat, &scores, ok, rejected, lost);
    Ok((v, scores))
}

fn verdict(
    mode: &'static str,
    cfg: &LoadCfg,
    elapsed: Duration,
    lat: Samples,
    scores: &[Scored],
    ok: usize,
    rejected: usize,
    lost: usize,
) -> Verdict {
    let elapsed_s = elapsed.as_secs_f64().max(1e-9);
    let mut epochs: Vec<u32> = scores.iter().map(|&(_, e, _)| e).collect();
    epochs.sort_unstable();
    epochs.dedup();
    let (mean_s, p50_s, p99_s, p999_s) = if lat.is_empty() {
        (0.0, 0.0, 0.0, 0.0)
    } else {
        let s = lat.summary();
        (s.mean, s.p50, s.p99, lat.percentile(99.9))
    };
    Verdict {
        mode,
        requests: cfg.requests,
        ok,
        rejected,
        lost,
        elapsed_s,
        predictions_per_s: ok as f64 / elapsed_s,
        mean_s,
        p50_s,
        p99_s,
        p999_s,
        epochs_seen: epochs,
        bitwise_checked: None,
    }
}

/// Re-score every ok response locally and require bit equality with
/// the training-side forward. The checked count lands in the verdict
/// so CI can assert it is nonzero.
pub fn verify_bitwise(
    verdict: &mut Verdict,
    scores: &[Scored],
    model: &Model,
    precision: u32,
    seed: u64,
) -> Result<()> {
    let mut core = ShardCore::new(precision);
    for &(id, _epoch, got) in scores {
        let want = expected_score(&mut core, model, seed, id);
        if want.to_bits() != got.to_bits() {
            anyhow::bail!(
                "request {id}: served {got} ({:#010x}) != training forward {want} ({:#010x})",
                got.to_bits(),
                want.to_bits()
            );
        }
    }
    verdict.bitwise_checked = Some(scores.len());
    Ok(())
}

/// Serialize a verdict as the CI-facing JSON report.
pub fn write_report(path: &Path, v: &Verdict) -> Result<()> {
    let epochs: Vec<String> = v.epochs_seen.iter().map(|e| e.to_string()).collect();
    let bitwise = match v.bitwise_checked {
        Some(n) => format!("{n}"),
        None => "null".to_string(),
    };
    let json = format!(
        "{{\n  \"mode\": \"{}\",\n  \"requests\": {},\n  \"ok\": {},\n  \"rejected\": {},\n  \
         \"lost\": {},\n  \"elapsed_s\": {:.6},\n  \"predictions_per_s\": {:.1},\n  \
         \"mean_s\": {:.9},\n  \"p50_s\": {:.9},\n  \"p99_s\": {:.9},\n  \"p999_s\": {:.9},\n  \
         \"epochs_seen\": [{}],\n  \"bitwise_checked\": {}\n}}\n",
        v.mode,
        v.requests,
        v.ok,
        v.rejected,
        v.lost,
        v.elapsed_s,
        v.predictions_per_s,
        v.mean_s,
        v.p50_s,
        v.p99_s,
        v.p999_s,
        epochs.join(", "),
        bitwise
    );
    std::fs::write(path, json).with_context(|| format!("writing {}", path.display()))
}
