"""L2 model semantics: fused step trains, losses behave, shapes hold."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels.ref import (
    dequantize,
    loss_ref,
    pack_bitplanes,
    quantize,
    stable_sigmoid,
)


def make_dataset(rng, n, d, loss="logreg"):
    """Linearly-separable-ish synthetic task in [0,1) feature space.

    The last feature is a constant bias column so the affine target is
    representable by the bias-free GLM (mirrors data/synth.rs in Rust).
    """
    a = rng.random((n, d), dtype=np.float32)
    a[:, -1] = 0.999
    w_true = (rng.standard_normal(d) / np.sqrt(d)).astype(np.float32)
    w_true[-1] = 0.0
    logits = 4.0 * ((a - 0.5) @ w_true)
    if loss == "logreg":
        y = (logits > 0).astype(np.float32)
    elif loss == "svm":
        y = np.where(logits > 0, 1.0, -1.0).astype(np.float32)
    else:
        y = logits.astype(np.float32)
    return a, y


class TestForwardPartial:
    def test_shapes(self):
        rng = np.random.default_rng(0)
        a, _ = make_dataset(rng, 8, 256)
        planes = pack_bitplanes(quantize(jnp.asarray(a)))
        x = jnp.zeros(256)
        pa = model.forward_partial(planes, x)
        assert pa.shape == (8,)

    def test_model_parallel_decomposition(self):
        """sum of per-partition PA == whole-model PA (the C1 invariant)."""
        rng = np.random.default_rng(1)
        a, _ = make_dataset(rng, 8, 512)
        x = rng.standard_normal(512).astype(np.float32)
        whole = model.forward_partial(
            pack_bitplanes(quantize(jnp.asarray(a))), jnp.asarray(x)
        )
        parts = []
        for m in range(4):
            sl = slice(m * 128, (m + 1) * 128)
            parts.append(
                model.forward_partial(
                    pack_bitplanes(quantize(jnp.asarray(a[:, sl]))),
                    jnp.asarray(x[sl]),
                )
            )
        np.testing.assert_allclose(
            np.asarray(whole), np.asarray(sum(parts)), rtol=1e-4, atol=1e-5
        )


class TestLocalStep:
    @pytest.mark.parametrize("loss", ["linreg", "logreg", "svm"])
    def test_loss_decreases(self, loss):
        rng = np.random.default_rng(2)
        mb, d, steps = 8, 256, 60
        a, y = make_dataset(rng, mb * steps, d, loss)
        x = jnp.zeros(d)
        lr = jnp.asarray([{"linreg": 0.01, "logreg": 0.5, "svm": 0.1}[loss]], jnp.float32)
        inv_b = jnp.asarray([1.0 / mb], jnp.float32)
        losses = []
        for epoch in range(4):
            for s in range(steps):
                chunk = a[s * mb : (s + 1) * mb]
                q = quantize(jnp.asarray(chunk))
                planes = pack_bitplanes(q)
                aq = dequantize(q)
                x, lsum = model.local_step(
                    planes, aq, x, jnp.asarray(y[s * mb : (s + 1) * mb]), lr, inv_b, loss
                )
                losses.append(float(lsum))
        head = np.mean(losses[:steps])
        tail = np.mean(losses[-steps:])
        assert tail < 0.7 * head, f"{loss}: loss {head} -> {tail} did not decrease"

    def test_step_matches_manual_composition(self):
        rng = np.random.default_rng(3)
        mb, d = 8, 256
        a, y = make_dataset(rng, mb, d, "logreg")
        q = quantize(jnp.asarray(a))
        planes, aq = pack_bitplanes(q), dequantize(q)
        x = jnp.asarray(rng.standard_normal(d).astype(np.float32))
        lr = jnp.asarray([0.1], jnp.float32)
        inv_b = jnp.asarray([1.0 / mb], jnp.float32)
        x2, _ = model.local_step(planes, aq, x, jnp.asarray(y), lr, inv_b, "logreg")
        fa = model.forward_partial(planes, x)
        g = model.backward_partial(aq, fa, jnp.asarray(y), jnp.zeros(d), lr, "logreg")
        x_manual = model.apply_update(x, g, inv_b)
        np.testing.assert_allclose(np.asarray(x2), np.asarray(x_manual), rtol=1e-5, atol=1e-6)


class TestLosses:
    def test_logreg_loss_at_zero_logits(self):
        fa = jnp.zeros(8)
        y = jnp.asarray([0.0, 1.0] * 4)
        # -log(0.5) per sample
        np.testing.assert_allclose(float(loss_ref(fa, y, "logreg")), 8 * np.log(2), rtol=1e-5)

    def test_svm_margin_satisfied_is_zero(self):
        fa = jnp.asarray([2.0, -3.0])
        y = jnp.asarray([1.0, -1.0])
        assert float(loss_ref(fa, y, "svm")) == 0.0

    def test_sigmoid_stability(self):
        z = jnp.asarray([-1e4, -60.0, 0.0, 60.0, 1e4])
        s = np.asarray(stable_sigmoid(z))
        assert np.all(np.isfinite(s))
        np.testing.assert_allclose(s[2], 0.5)
        assert s[0] < 1e-20 and s[-1] > 1 - 1e-7
