"""L1 backward kernel vs oracle: gradient accumulation + update."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import bwd
from compile.kernels.ref import backward_ref, grad_scale, update_ref


def run_kernel(a, scale, g, block_d=bwd.DEFAULT_BLOCK_D):
    return np.asarray(
        bwd.accumulate_grad(jnp.asarray(a), jnp.asarray(scale), jnp.asarray(g), block_d)
    )


class TestBackwardKernel:
    def test_matches_ref_linreg(self):
        rng = np.random.default_rng(0)
        mb, d = 8, 1024
        a = rng.random((mb, d), dtype=np.float32)
        fa = rng.standard_normal(mb).astype(np.float32)
        y = rng.standard_normal(mb).astype(np.float32)
        g = rng.standard_normal(d).astype(np.float32)
        scale = np.asarray(grad_scale(jnp.asarray(fa), jnp.asarray(y), 0.1, "linreg"))
        got = run_kernel(a, scale, g)
        want = np.asarray(
            backward_ref(jnp.asarray(a), jnp.asarray(fa), jnp.asarray(y),
                         jnp.asarray(g), 0.1, "linreg")
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_scale_is_identity(self):
        rng = np.random.default_rng(1)
        a = rng.random((8, 256), dtype=np.float32)
        g = rng.standard_normal(256).astype(np.float32)
        got = run_kernel(a, np.zeros(8, np.float32), g)
        np.testing.assert_array_equal(got, g)

    def test_accumulation_is_additive(self):
        """bwd(bwd(g, mb1), mb2) == g + contributions of both micro-batches."""
        rng = np.random.default_rng(2)
        a1 = rng.random((8, 256), dtype=np.float32)
        a2 = rng.random((8, 256), dtype=np.float32)
        s1 = rng.standard_normal(8).astype(np.float32)
        s2 = rng.standard_normal(8).astype(np.float32)
        g = np.zeros(256, np.float32)
        seq = run_kernel(a2, s2, run_kernel(a1, s1, g))
        direct = s1 @ a1 + s2 @ a2
        np.testing.assert_allclose(seq, direct, rtol=1e-4, atol=1e-5)

    def test_update(self):
        rng = np.random.default_rng(3)
        x = rng.standard_normal(512).astype(np.float32)
        g = rng.standard_normal(512).astype(np.float32)
        got = np.asarray(update_ref(jnp.asarray(x), jnp.asarray(g), 1.0 / 64))
        np.testing.assert_allclose(got, x - g / 64, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    mb=st.sampled_from([1, 2, 4, 8, 16]),
    d_blocks=st.integers(1, 6),
    loss=st.sampled_from(["linreg", "logreg", "svm"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_backward_property(mb, d_blocks, loss, seed):
    rng = np.random.default_rng(seed)
    d = d_blocks * 128
    a = rng.random((mb, d), dtype=np.float32)
    fa = rng.standard_normal(mb).astype(np.float32)
    if loss == "svm":
        y = rng.choice([-1.0, 1.0], mb).astype(np.float32)
    elif loss == "logreg":
        y = rng.choice([0.0, 1.0], mb).astype(np.float32)
    else:
        y = rng.standard_normal(mb).astype(np.float32)
    g = rng.standard_normal(d).astype(np.float32)
    lr = float(rng.uniform(1e-4, 1.0))
    scale = np.asarray(grad_scale(jnp.asarray(fa), jnp.asarray(y), lr, loss))
    got = run_kernel(a, scale, g, block_d=128)
    want = np.asarray(
        backward_ref(jnp.asarray(a), jnp.asarray(fa), jnp.asarray(y),
                     jnp.asarray(g), lr, loss)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
