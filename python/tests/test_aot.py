"""AOT path: lowering produces parseable HLO text with stable entry shapes."""

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.ref import LANE, PRECISION


def lower_fwd(d=256, mb=8):
    planes = jax.ShapeDtypeStruct((PRECISION, mb, d // LANE), jnp.uint32)
    x = jax.ShapeDtypeStruct((d,), jnp.float32)
    return jax.jit(model.forward_partial).lower(planes, x)


class TestHloText:
    def test_contains_entry(self):
        text = aot.to_hlo_text(lower_fwd())
        assert "ENTRY" in text and "HloModule" in text

    def test_entry_signature_shapes(self):
        text = aot.to_hlo_text(lower_fwd(d=256, mb=8))
        # bit-planes input and f32 model input must appear in the module
        assert "u32[4,8,8]" in text
        assert "f32[256]" in text

    def test_output_is_tuple(self):
        # return_tuple=True: rust unwraps with to_tuple1()
        text = aot.to_hlo_text(lower_fwd())
        assert "(f32[8]" in text  # root tuple with the PA vector inside

    def test_deterministic(self):
        assert aot.to_hlo_text(lower_fwd()) == aot.to_hlo_text(lower_fwd())


class TestVariants:
    def test_manifest_covers_all_kinds(self):
        kinds = {meta[0] for _, meta, _ in aot.build_variants()}
        assert kinds == {"fwd", "bwd", "step", "update", "loss"}

    def test_variant_count(self):
        n_d, n_mb, n_loss = len(aot.D_VARIANTS), len(aot.MB_VARIANTS), len(aot.LOSSES)
        want = n_d * n_mb * (1 + 2 * n_loss) + n_d + n_mb * n_loss
        assert len(list(aot.build_variants())) == want
