"""L1 forward kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (D multiples of the lane/block sizes, MB, P) and
data distributions; every case asserts the Pallas kernel, the jnp bit-plane
reference, and the dense-f32 ground truth agree.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitserial
from compile.kernels.ref import (
    LANE,
    PRECISION,
    dequantize,
    forward_dense_ref,
    forward_ref,
    numpy_pack_bitplanes,
    pack_bitplanes,
    plane_scales,
    quantize,
    unpack_bitplanes,
)


def make_case(rng, mb, d, precision=PRECISION):
    a = rng.random((mb, d), dtype=np.float32)
    q = np.asarray(quantize(a, precision))
    planes = pack_bitplanes(jnp.asarray(q), precision)
    x = rng.standard_normal(d).astype(np.float32)
    return q, planes, x


def kernel_pa(planes, x, block_d=bitserial.DEFAULT_BLOCK_D):
    per_plane = bitserial.forward_planes(jnp.asarray(planes), jnp.asarray(x), block_d)
    return np.asarray(plane_scales(planes.shape[0]) @ per_plane)


class TestPackRoundTrip:
    def test_pack_unpack_inverse(self):
        rng = np.random.default_rng(0)
        q, planes, _ = make_case(rng, 8, 256)
        bits = np.asarray(unpack_bitplanes(planes))
        for p in range(PRECISION):
            expect = (q >> (PRECISION - 1 - p)) & 1
            np.testing.assert_array_equal(bits[p], expect.astype(np.float32))

    def test_numpy_pack_matches_jnp_pack(self):
        rng = np.random.default_rng(1)
        q, planes, _ = make_case(rng, 4, 128)
        np.testing.assert_array_equal(numpy_pack_bitplanes(q), np.asarray(planes))

    def test_quantization_error_bound(self):
        rng = np.random.default_rng(2)
        a = rng.random((16, 64), dtype=np.float32)
        err = np.abs(np.asarray(dequantize(quantize(a))) - a)
        assert err.max() <= 2.0 ** (-PRECISION) + 1e-6

    def test_plane_scales_sum(self):
        # all-ones bits reconstruct the max level (2^P - 1) / 2^P
        s = float(np.sum(np.asarray(plane_scales())))
        assert abs(s - (2**PRECISION - 1) / 2**PRECISION) < 1e-7


class TestForwardKernel:
    def test_matches_bitplane_ref(self):
        rng = np.random.default_rng(3)
        q, planes, x = make_case(rng, 8, 1024)
        got = kernel_pa(planes, x)
        want = np.asarray(forward_ref(planes, jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_matches_dense_ground_truth(self):
        rng = np.random.default_rng(4)
        q, planes, x = make_case(rng, 8, 512)
        got = kernel_pa(planes, x)
        dense = np.asarray(forward_dense_ref(dequantize(jnp.asarray(q)), jnp.asarray(x)))
        np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)

    def test_single_grid_step(self):
        rng = np.random.default_rng(5)
        q, planes, x = make_case(rng, 8, 256)
        got = kernel_pa(planes, x, block_d=256)
        want = np.asarray(forward_ref(planes, jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_many_grid_steps(self):
        rng = np.random.default_rng(6)
        q, planes, x = make_case(rng, 8, 2048)
        got = kernel_pa(planes, x, block_d=128)
        want = np.asarray(forward_ref(planes, jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_model_gives_zero(self):
        rng = np.random.default_rng(7)
        _, planes, _ = make_case(rng, 8, 256)
        got = kernel_pa(planes, np.zeros(256, np.float32))
        np.testing.assert_array_equal(got, np.zeros(8, np.float32))

    def test_zero_features_inert_padding(self):
        """Zero-padded features must not change PA (Rust pads partitions)."""
        rng = np.random.default_rng(8)
        mb, d, dpad = 8, 512, 1024
        a = np.zeros((mb, dpad), dtype=np.float32)
        a[:, :d] = rng.random((mb, d), dtype=np.float32)
        planes = pack_bitplanes(quantize(jnp.asarray(a)))
        x = np.zeros(dpad, np.float32)
        x[:d] = rng.standard_normal(d).astype(np.float32)
        x[d:] = rng.standard_normal(dpad - d).astype(np.float32)  # garbage weights
        got = kernel_pa(np.asarray(planes), x)
        want = kernel_pa(
            np.asarray(pack_bitplanes(quantize(jnp.asarray(a[:, :d])))), x[:d]
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    mb=st.sampled_from([1, 2, 4, 8, 16]),
    d_blocks=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_forward_kernel_property(mb, d_blocks, seed):
    """Kernel == bit-plane ref == dense ref for random shapes/data."""
    rng = np.random.default_rng(seed)
    d = d_blocks * 128
    q, planes, x = make_case(rng, mb, d)
    got = kernel_pa(planes, x, block_d=128)
    want = np.asarray(forward_ref(planes, jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    dense = np.asarray(forward_dense_ref(dequantize(jnp.asarray(q)), jnp.asarray(x)))
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), precision=st.sampled_from([1, 2, 4, 8]))
def test_forward_kernel_any_precision(seed, precision):
    """MLWeaving is any-precision: the kernel works for P in {1,2,4,8}."""
    rng = np.random.default_rng(seed)
    a = rng.random((8, 256), dtype=np.float32)
    q = quantize(jnp.asarray(a), precision)
    planes = pack_bitplanes(q, precision)
    x = rng.standard_normal(256).astype(np.float32)
    got = kernel_pa(np.asarray(planes), x)
    dense = np.asarray(forward_dense_ref(dequantize(q, precision), jnp.asarray(x)))
    np.testing.assert_allclose(got, dense, rtol=1e-4, atol=1e-4)
