"""Ensure `compile` package imports when pytest runs from the repo root."""
