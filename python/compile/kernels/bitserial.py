"""L1 Pallas kernel: bit-serial (bit-weaving) forward pass.

This is the TPU re-thinking of the paper's FPGA hot spot (§Hardware-
Adaptation in DESIGN.md). The FPGA consumes one bit of 64 features per
cycle through 64 bit-serial multipliers + an adder tree (MLWeaving). The
transferable insight is the algebraic identity

    PA = sum_p 2^{-(p+1)} * (bits_p . x)

i.e. a P-bit quantized matvec is P *binary* matvecs. On TPU:

* samples stay **bit-plane packed** in HBM (uint32, 32 features/lane) —
  the dominant memory traffic is D*P/8 bytes instead of 4*D bytes, the
  same 8x (P=4) traffic reduction the FPGA gets from its HBM channels;
* the BlockSpec grid streams D in VMEM-sized blocks (the analogue of the
  per-engine HBM channel schedule of paper Fig. 6);
* inside the kernel the planes are unpacked with shifts/masks (VPU work)
  and reduced with a (P*MB, Db) x (Db,) matmul (MXU work), accumulating
  across grid steps in the output ref.

The per-plane 2^{-(p+1)} scaling is fused by the caller (model.py) — it is
a (P,)x(P,MB) contraction, negligible.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated on CPU, TPU-viability is argued by
VMEM/MXU accounting in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LANE

# Default feature-block width. 512 features = 16 packed lanes per plane.
# VMEM accounting at the default (P=4, MB=8, DB=512):
#   planes block  4*8*16  u32  =  2 KiB
#   x block       512     f32  =  2 KiB
#   unpacked bits 4*8*512 f32  = 64 KiB   (the big intermediate)
#   acc           4*8     f32  = 128 B
# comfortably < 16 MiB/core even at DB=8192.
DEFAULT_BLOCK_D = 512


def _fwd_kernel(planes_ref, x_ref, acc_ref):
    """One grid step: accumulate per-plane partial dot products.

    planes_ref: u32[P, MB, DB/32] packed bit-planes for this feature block
    x_ref:      f32[DB]           model block
    acc_ref:    f32[P, MB]        per-plane accumulator (carried across grid)
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    planes = planes_ref[...]                     # (P, MB, W)
    p, mb, w = planes.shape
    shifts = jnp.arange(LANE, dtype=jnp.uint32)
    # VPU: unpack 32 features per lane -> (P, MB, DB) in {0.0, 1.0}.
    bits = ((planes[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.float32)
    bits = bits.reshape(p * mb, w * LANE)
    # MXU: binary matvec for all planes at once.
    contrib = jnp.dot(bits, x_ref[...], preferred_element_type=jnp.float32)
    acc_ref[...] += contrib.reshape(p, mb)


@functools.partial(jax.jit, static_argnames=("block_d",))
def forward_planes(planes, x, block_d: int = DEFAULT_BLOCK_D):
    """Per-plane partial activations: u32[P,MB,D/32], f32[D] -> f32[P,MB].

    The caller applies the plane scaling (see model.forward_partial).
    """
    p, mb, w = planes.shape
    d = w * LANE
    assert x.shape == (d,), (x.shape, d)
    bd = min(block_d, d)
    assert d % bd == 0, f"D={d} not a multiple of block {bd}"
    grid = (d // bd,)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, mb, bd // LANE), lambda i: (0, 0, i)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((p, mb), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, mb), jnp.float32),
        interpret=True,
    )(planes, x)
