"""Pure-jnp reference oracles for the P4SGD kernels.

Everything in this file is the *specification*: the Pallas kernels
(`bitserial.py`, `bwd.py`), the Rust native bit-serial engine
(`rust/src/engine/bitserial.rs`), and the AOT artifacts are all tested
against these functions.

Quantization follows MLWeaving (paper §4.1.2): features are normalized to
[0, 1) and quantized to ``P`` bits, so a feature value is reconstructed as

    a  =  sum_p  bit_p * 2^{-(p+1)}          (bit_0 = MSB)

which makes the P-bit dot product a sum of P binary dot products:

    PA = a . x = sum_p 2^{-(p+1)} * (bits_p . x)

That identity is what the FPGA exploits with bit-serial multipliers and
what the Pallas kernel exploits with per-plane MXU matmuls.
"""

import jax.numpy as jnp
import numpy as np

# Fixed-point precision of the bit-weaving path (paper uses 4 bits).
PRECISION = 4
# Features per packed 32-bit lane.
LANE = 32


def quantize(a, precision: int = PRECISION):
    """Quantize features in [0, 1) to ``precision``-bit integer levels."""
    levels = (1 << precision) - 1
    q = jnp.floor(jnp.clip(a, 0.0, 1.0 - 1e-7) * (1 << precision))
    return jnp.clip(q, 0, levels).astype(jnp.uint32)


def dequantize(q, precision: int = PRECISION):
    """Reconstruct the fixed-point value encoded by ``quantize``."""
    return q.astype(jnp.float32) / jnp.float32(1 << precision)


def pack_bitplanes(q, precision: int = PRECISION):
    """Pack quantized samples into bit-planes.

    q: uint32[MB, D] quantization levels, D a multiple of 32.
    Returns uint32[P, MB, D // 32]; plane p holds bit (P-1-p) of every
    feature (plane 0 = MSB); feature j lives in word j//32, bit j%32.
    """
    mb, d = q.shape
    assert d % LANE == 0, f"D={d} must be a multiple of {LANE}"
    planes = []
    for p in range(precision):
        bit = (q >> (precision - 1 - p)) & 1  # (MB, D)
        lanes = bit.reshape(mb, d // LANE, LANE).astype(jnp.uint32)
        shifts = jnp.arange(LANE, dtype=jnp.uint32)
        planes.append(jnp.sum(lanes << shifts, axis=-1, dtype=jnp.uint32))
    return jnp.stack(planes)  # (P, MB, D//32)


def unpack_bitplanes(planes):
    """Inverse of ``pack_bitplanes``: uint32[P, MB, W] -> f32[P, MB, 32*W]."""
    p, mb, w = planes.shape
    shifts = jnp.arange(LANE, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(p, mb, w * LANE).astype(jnp.float32)


def plane_scales(precision: int = PRECISION):
    """Per-plane weights 2^{-(p+1)}, plane 0 = MSB."""
    return jnp.float32(2.0) ** (-(jnp.arange(precision, dtype=jnp.float32) + 1))


def forward_ref(planes, x):
    """Reference forward pass: partial activations from bit-planes.

    planes: uint32[P, MB, D//32], x: f32[D] -> PA f32[MB].
    Mathematically identical to ``dequantize(q) @ x``.
    """
    bits = unpack_bitplanes(planes)            # (P, MB, D)
    per_plane = jnp.einsum("pmd,d->pm", bits, x)
    return jnp.einsum("p,pm->m", plane_scales(planes.shape[0]), per_plane)


def forward_dense_ref(a, x):
    """Dense-f32 forward used for cross-checking: a f32[MB, D] @ x f32[D]."""
    return a @ x


def stable_sigmoid(z):
    """Numerically-stable sigmoid (matches the Rust implementation)."""
    zc = jnp.clip(z, -60.0, 60.0)
    return jnp.where(
        zc >= 0,
        1.0 / (1.0 + jnp.exp(-zc)),
        jnp.exp(zc) / (1.0 + jnp.exp(zc)),
    )


def grad_scale(fa, y, lr, loss: str):
    """scale[k] = lr * df(FA[k], y[k]) — paper Alg. 1 line 27.

    linreg:  df = fa - y
    logreg:  df = sigmoid(fa) - y          (y in {0, 1})
    svm:     df = -y if y*fa < 1 else 0    (y in {-1, +1}, hinge)
    """
    if loss == "linreg":
        df = fa - y
    elif loss == "logreg":
        df = stable_sigmoid(fa) - y
    elif loss == "svm":
        df = jnp.where(y * fa < 1.0, -y, jnp.zeros_like(y))
    else:
        raise ValueError(f"unknown loss {loss!r}")
    return lr * df


def backward_ref(a, fa, y, g, lr, loss: str):
    """Reference backward: g' = g + sum_k scale[k] * a[k] (Alg. 1 line 28)."""
    scale = grad_scale(fa, y, lr, loss)
    return g + scale @ a


def update_ref(x, g, inv_b):
    """Model update x' = x - g * (1/B) (Alg. 1 line 31)."""
    return x - g * inv_b


def loss_ref(fa, y, loss: str):
    """Per-sample training loss summed over the micro-batch."""
    if loss == "linreg":
        r = fa - y
        return 0.5 * jnp.sum(r * r)
    if loss == "logreg":
        # Stable binary cross-entropy from logits, y in {0, 1}.
        return jnp.sum(jnp.maximum(fa, 0.0) - fa * y + jnp.log1p(jnp.exp(-jnp.abs(fa))))
    if loss == "svm":
        return jnp.sum(jnp.maximum(0.0, 1.0 - y * fa))
    raise ValueError(f"unknown loss {loss!r}")


def numpy_pack_bitplanes(q: np.ndarray, precision: int = PRECISION) -> np.ndarray:
    """Numpy twin of ``pack_bitplanes`` for test-data generation."""
    mb, d = q.shape
    assert d % LANE == 0
    out = np.zeros((precision, mb, d // LANE), dtype=np.uint32)
    for p in range(precision):
        bit = (q >> (precision - 1 - p)) & 1
        for j in range(d):
            out[p, :, j // LANE] |= (bit[:, j].astype(np.uint32)) << np.uint32(j % LANE)
    return out
