"""L1 Pallas kernel: backward pass (gradient accumulation).

Paper Alg. 1 lines 25-29: the worker turns the full activations FA into
per-sample scales and accumulates rank-1 updates into its partial gradient:

    g' = g + sum_k scale[k] * A[k, :]

On the FPGA this reuses the 64 bit-serial multipliers with the sample bits
replayed from a FIFO. On TPU the natural shape is a dense (MB,) x (MB, D)
matvec: one MXU contraction per feature block, fused with the += so the
gradient makes a single HBM round trip. Feature blocks are independent, so
the grid carries no accumulator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _bwd_kernel(a_ref, scale_ref, g_ref, out_ref):
    """out[blk] = g[blk] + scale . A[:, blk] for one feature block."""
    out_ref[...] = g_ref[...] + jnp.dot(
        scale_ref[...], a_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_d",))
def accumulate_grad(a, scale, g, block_d: int = DEFAULT_BLOCK_D):
    """g' = g + scale @ a.

    a: f32[MB, D] dequantized micro-batch, scale: f32[MB], g: f32[D].
    """
    mb, d = a.shape
    assert scale.shape == (mb,) and g.shape == (d,)
    bd = min(block_d, d)
    assert d % bd == 0
    grid = (d // bd,)
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((mb, bd), lambda i: (0, i)),
            pl.BlockSpec((mb,), lambda i: (0,)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(a, scale, g)
