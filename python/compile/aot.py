"""AOT lowering: JAX/Pallas -> HLO *text* -> artifacts/ for the Rust runtime.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts

Emits one artifact per (kind, D, MB, loss) variant plus `manifest.txt`
(`kind d mb loss path` per line) which `rust/src/runtime/artifacts.rs`
parses. All entry points are lowered with donatable running state where
applicable and return_tuple=True (unwrap with `to_tuple1()` etc. on the
Rust side).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.ref import LANE, PRECISION

# Feature-partition sizes the Rust side can pick from (it pads up).
D_VARIANTS = (256, 1024, 4096)
# Micro-batch size: 8 banks per engine in the paper's worker.
MB_VARIANTS = (8,)
LOSSES = ("linreg", "logreg", "svm")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_variants():
    """Yield (name, lowered) for every artifact we ship."""
    for d in D_VARIANTS:
        for mb in MB_VARIANTS:
            planes = _spec((PRECISION, mb, d // LANE), jnp.uint32)
            x = _spec((d,))
            a = _spec((mb, d))
            fa = _spec((mb,))
            y = _spec((mb,))
            g = _spec((d,))
            scalar = _spec((1,))

            yield (
                f"fwd_d{d}_mb{mb}",
                ("fwd", d, mb, "-"),
                jax.jit(model.forward_partial).lower(planes, x),
            )
            for loss in LOSSES:
                yield (
                    f"bwd_{loss}_d{d}_mb{mb}",
                    ("bwd", d, mb, loss),
                    jax.jit(
                        functools.partial(model.backward_partial, loss=loss)
                    ).lower(a, fa, y, g, scalar),
                )
                yield (
                    f"step_{loss}_d{d}_mb{mb}",
                    ("step", d, mb, loss),
                    jax.jit(functools.partial(model.local_step, loss=loss)).lower(
                        planes, a, x, y, scalar, scalar
                    ),
                )
        yield (
            f"update_d{d}",
            ("update", d, 0, "-"),
            jax.jit(model.apply_update).lower(_spec((d,)), _spec((d,)), _spec((1,))),
        )
    for mb in MB_VARIANTS:
        fa = _spec((mb,))
        y = _spec((mb,))
        for loss in LOSSES:
            yield (
                f"loss_{loss}_mb{mb}",
                ("loss", 0, mb, loss),
                jax.jit(functools.partial(model.loss_sum, loss=loss)).lower(fa, y),
            )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, (kind, d, mb, loss), lowered in build_variants():
        path = f"{name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest.append(f"{kind} {d} {mb} {loss} {path}")
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts -> {args.out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
