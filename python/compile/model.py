"""L2: the GLM model — JAX forward/backward step functions calling the
Pallas kernels.

These are the functions `aot.py` lowers to HLO text for the Rust runtime.
Each maps 1:1 onto a stage of paper Algorithm 1:

  forward_partial   Alg. 1 lines 18-21  (stage 1, per worker, per micro-batch)
  backward_partial  Alg. 1 lines 25-29  (stage 3)
  apply_update      Alg. 1 line 31
  loss_sum          convergence metric for Figs. 14/15
  local_step        fused single-worker iteration (quickstart path)

The communication stage (Alg. 1 lines 22-23) lives entirely in Rust — the
switch aggregates the `PA` these functions produce.
"""

import jax.numpy as jnp

from .kernels import bitserial, bwd
from .kernels.ref import grad_scale, loss_ref, plane_scales


def forward_partial(planes, x):
    """Partial activations PA_m = A_m . x_m from bit-planes.

    planes: u32[P, MB, D/32], x: f32[D] -> f32[MB]
    """
    per_plane = bitserial.forward_planes(planes, x)       # (P, MB)
    return plane_scales(planes.shape[0]) @ per_plane      # (MB,)


def backward_partial(a, fa, y, g, lr, loss: str):
    """Accumulate this micro-batch's gradient contribution.

    a: f32[MB, D] dequantized partition, fa: f32[MB] full activations
    (switch output), y: f32[MB] labels, g: f32[D] running gradient,
    lr: f32[1] learning rate -> g' f32[D].
    """
    scale = grad_scale(fa, y, lr[0], loss)                # (MB,)
    return bwd.accumulate_grad(a, scale, g)


def apply_update(x, g, inv_b):
    """x' = x - g * (1/B): the end-of-mini-batch model update."""
    return x - g * inv_b[0]


def loss_sum(fa, y, loss: str):
    """Summed training loss of one micro-batch (for loss-vs-epoch curves)."""
    return loss_ref(fa, y, loss)


def local_step(planes, a, x, y, lr, inv_b, loss: str):
    """Fused single-worker iteration over ONE micro-batch mini-batch.

    With M = 1 worker the full activation equals the partial activation, so
    forward -> scale -> gradient -> update runs in one artifact. Returns
    (x', loss_sum). Used by examples/quickstart.rs.
    """
    fa = forward_partial(planes, x)
    g0 = jnp.zeros_like(x)
    g = backward_partial(a, fa, y, g0, lr, loss)
    x_new = apply_update(x, g, inv_b)
    return x_new, loss_sum(fa, y, loss)
