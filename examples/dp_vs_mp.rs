//! Data-parallel vs model-parallel, functionally and in packets — the
//! executable version of the paper's core argument (Figs. 1 & 9).
//!
//!     cargo run --release --example dp_vs_mp
//!
//! Trains the same dataset both ways over the same P4 switch substrate
//! and compares (a) convergence, (b) network traffic. MP ships B
//! activations per iteration; DP ships D gradients — the packet counters
//! make the asymmetry concrete.

use p4sgd::config::SystemConfig;
use p4sgd::coordinator::{dp, mp};
use p4sgd::data::synth;
use p4sgd::engine::{Compute, NativeCompute};
use p4sgd::glm::Loss;
use p4sgd::protocol::HEADER_BYTES;

fn main() {
    let mut cfg = SystemConfig::default();
    cfg.cluster.workers = 4;
    cfg.cluster.engines = 2;
    cfg.cluster.slots = 16;
    cfg.train.loss = Loss::LogReg;
    cfg.train.lr = 1.0;
    cfg.train.batch = 64;
    cfg.train.micro_batch = 8;
    cfg.train.epochs = 6;
    cfg.net.latency_ns = 0;
    cfg.net.timeout_us = 3000;
    cfg.validate().expect("config");

    let ds = synth::table2_like("real_sim", 512, 4096, cfg.train.loss, 11);
    println!("dataset: {} | {} workers, B={}", ds.name, cfg.cluster.workers, cfg.train.batch);

    let make = |_w: usize, _e: usize| -> Box<dyn Compute> { Box::new(NativeCompute) };
    let mp_rep = mp::train_mp(&cfg, &ds, &make);
    let dp_rep = dp::train_dp(&cfg, &ds, &make);

    println!("\n{:<8}{:>14}{:>14}", "epoch", "MP loss", "DP loss");
    for e in 0..cfg.train.epochs {
        println!(
            "{:<8}{:>14.5}{:>14.5}",
            e,
            mp_rep.loss_per_epoch[e] / ds.n as f32,
            dp_rep.loss_per_epoch[e] / ds.n as f32
        );
    }

    let mp_bytes = mp_rep.agg.pa_sent * (HEADER_BYTES as u64 + 4 * cfg.train.micro_batch as u64);
    let dp_bytes = dp_rep.agg.pa_sent * (HEADER_BYTES as u64 + 4 * 64);
    println!("\nnetwork traffic (worker->switch):");
    println!("  MP: {:>10} packets {:>12} bytes  (payload = MB activations)", mp_rep.agg.pa_sent, mp_bytes);
    println!("  DP: {:>10} packets {:>12} bytes  (payload = D-gradient chunks)", dp_rep.agg.pa_sent, dp_bytes);
    println!(
        "  DP/MP traffic ratio: {:.1}x — the paper's Table 1 network column, live",
        dp_bytes as f64 / mp_bytes as f64
    );
}
