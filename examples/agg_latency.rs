//! AllReduce latency through the three aggregation servers — the
//! functional companion to paper Fig. 8.
//!
//!     cargo run --release --example agg_latency
//!
//! Runs the *real* protocol state machines (P4SGD Algorithm 2/3,
//! SwitchML shadow-copy pools, host parameter server) over the
//! in-process fabric and reports wall-clock whiskers. Injected latency
//! is zero, so what you see is each protocol's overhead floor on this
//! software substrate; the paper-testbed shapes come from
//! `p4sgd repro fig8`.

use p4sgd::config::NetConfig;
use p4sgd::metrics::LatencyHist;
use p4sgd::net::sim::SimNet;
use p4sgd::net::{switch_node, Transport};
use p4sgd::protocol::Packet;
use p4sgd::switch::host_ps::HostPs;
use p4sgd::switch::p4::P4Switch;
use p4sgd::switch::runner;
use p4sgd::switch::switchml::SwitchMlSwitch;
use p4sgd::worker::AggClient;
use std::time::{Duration, Instant};

const WORKERS: usize = 4;
const OPS: usize = 1_000;

fn main() {
    let net = NetConfig { latency_ns: 0, jitter_ns: 0, timeout_us: 5000, ..NetConfig::default() };

    // --- P4SGD (Algorithm 2/3, explicit ACK round) ---
    let hist = run_p4(&net);
    println!("P4SGD    (alg. 2/3) : {}", hist.whiskers());

    // --- SwitchML (shadow pools, implicit delayed ACK) ---
    let hist = run_pooled(&net, "switchml");
    println!("SwitchML (shadow)   : {}", hist.whiskers());

    // --- Host parameter server ---
    let hist = run_pooled(&net, "hostps");
    println!("Host PS  (unicast)  : {}", hist.whiskers());
}

fn run_p4(net: &NetConfig) -> LatencyHist {
    let mut eps = SimNet::build(WORKERS + 1, net);
    let server = runner::spawn(
        P4Switch::new(p4sgd::worker::agg_client::SEQ_SPACE, WORKERS, 8),
        eps.pop().unwrap(),
    );
    let mut hist = LatencyHist::new();
    std::thread::scope(|scope| {
        let mut it = eps.into_iter().enumerate();
        let (_, ep0) = it.next().unwrap();
        for (w, ep) in it {
            scope.spawn(move || {
                let mut agg = AggClient::new(ep, switch_node(WORKERS), w, 64, Duration::from_millis(5));
                for _ in 0..OPS {
                    let _ = agg.allreduce(&[1i32; 8]);
                }
            });
        }
        let mut agg = AggClient::new(ep0, switch_node(WORKERS), 0, 64, Duration::from_millis(5));
        for _ in 0..OPS {
            let t = Instant::now();
            let _ = agg.allreduce(&[1i32; 8]);
            hist.push_ns(t.elapsed().as_nanos() as f64);
        }
    });
    server.shutdown();
    hist
}

/// SwitchML and the host PS share a client shape: seq carries a parity
/// bit, the completed broadcast is the implicit ACK.
fn run_pooled(net: &NetConfig, which: &str) -> LatencyHist {
    let mut eps = SimNet::build(WORKERS + 1, net);
    let server: runner::ServerHandle = match which {
        "switchml" => runner::spawn(SwitchMlSwitch::new(64, WORKERS, 8), eps.pop().unwrap()),
        _ => runner::spawn(HostPs::new(64, WORKERS, 8), eps.pop().unwrap()),
    };
    let mut hist = LatencyHist::new();
    std::thread::scope(|scope| {
        let mut it = eps.into_iter().enumerate();
        let (_, ep0) = it.next().unwrap();
        for (w, ep) in it {
            scope.spawn(move || pooled_worker(ep, w, None));
        }
        pooled_worker(ep0, 0, Some(&mut hist));
    });
    server.shutdown();
    hist
}

fn pooled_worker(mut ep: p4sgd::net::sim::SimEndpoint, w: usize, mut hist: Option<&mut LatencyHist>) {
    let server = switch_node(WORKERS);
    for op in 0..OPS {
        let slot = (op % 64) as u16;
        let parity = ((op / 64) % 2) as u16;
        let seq = slot | (parity << 15);
        let pkt = Packet::pa(seq, w, vec![1i32; 8]);
        let t = Instant::now();
        ep.send(server, &pkt);
        // wait for this op's broadcast (retransmit on 5ms timeouts)
        loop {
            match ep.recv_timeout(Duration::from_millis(5)) {
                Some((_, got)) if got.seq == seq && got.acked => break,
                Some(_) => continue,
                None => ep.send(server, &pkt),
            }
        }
        if let Some(h) = hist.as_deref_mut() {
            h.push_ns(t.elapsed().as_nanos() as f64);
        }
    }
}
