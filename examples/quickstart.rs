//! Quickstart: train a logistic-regression GLM on one simulated worker
//! using the AOT-compiled JAX/Pallas artifacts (the accelerator path).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end slice of the stack: quantize ->
//! bit-plane pack -> PJRT `step` artifact (forward kernel + backward
//! kernel + update fused) -> loss curve. No network is involved
//! (M = 1, so the full activation equals the partial activation).

use p4sgd::data::quantize::{dequantized_rows, pack_rows, LANE};
use p4sgd::data::synth;
use p4sgd::glm::Loss;
use p4sgd::runtime::Runtime;
use p4sgd::util::round_up;

fn main() -> anyhow::Result<()> {
    let (n, d, mb, epochs) = (512usize, 256usize, 8usize, 10usize);
    let lr = 0.5f32;
    let ds = synth::separable(n, d, Loss::LogReg, 0.1, 42);
    println!("dataset: {} samples x {} features (synthetic separable)", ds.n, ds.d);

    let mut rt = Runtime::load_default()?;
    println!("runtime: {} artifacts loaded", rt.manifest().entries.len());

    let d_pad = round_up(d, LANE);
    let mut x = vec![0.0f32; d_pad];
    let inv_b = 1.0 / mb as f32;

    for epoch in 0..epochs {
        let mut loss_sum = 0.0f32;
        for m in 0..n / mb {
            let rows = ds.rows(m * mb, (m + 1) * mb);
            let planes = pack_rows(rows, mb, d, d_pad, 4);
            let a_dq = dequantized_rows(rows, mb, d, d_pad, 4);
            let y = &ds.labels[m * mb..(m + 1) * mb];
            let (x_new, l) = rt.step(Loss::LogReg, &planes, &a_dq, &x, y, lr, inv_b)?;
            x = x_new;
            loss_sum += l;
        }
        println!("epoch {epoch:>2}: loss/sample {:.5}", loss_sum / n as f32);
    }
    println!("done — the L1 Pallas kernels ran via PJRT; python was never on this path");
    Ok(())
}
