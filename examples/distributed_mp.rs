//! Distributed model-parallel training — the paper's headline scenario.
//!
//!     cargo run --release --example distributed_mp
//!
//! Spins up 8 FPGA-worker threads + the P4-switch thread over the
//! simulated fabric **with packet loss injected**, trains a logistic
//! regression under model parallelism with the FCB micro-batch pipeline,
//! and reports the loss curve plus protocol counters — demonstrating
//! that the in-switch aggregation protocol (Algorithms 2/3) keeps
//! synchronous-SGD numerics bit-sane under an unreliable network.

use p4sgd::config::SystemConfig;
use p4sgd::coordinator::mp;
use p4sgd::data::synth;
use p4sgd::engine::{Compute, NativeCompute};
use p4sgd::glm::Loss;

fn main() {
    let mut cfg = SystemConfig::default();
    cfg.cluster.workers = 8;
    cfg.cluster.engines = 4;
    cfg.cluster.slots = 16;
    cfg.train.loss = Loss::LogReg;
    cfg.train.lr = 2.0;
    cfg.train.batch = 64;
    cfg.train.micro_batch = 8;
    cfg.train.epochs = 10;
    // a hostile network: 2% loss, latency + jitter, duplicates
    cfg.net.latency_ns = 5_000;
    cfg.net.jitter_ns = 1_000;
    cfg.net.drop_prob = 0.02;
    cfg.net.dup_prob = 0.01;
    cfg.net.timeout_us = 400;
    cfg.validate().expect("config");

    let ds = synth::table2_like("rcv1", 1024, 4096, cfg.train.loss, 7);
    println!(
        "training {} over {} workers x {} engines (drop={}, dup={})",
        ds.name, cfg.cluster.workers, cfg.cluster.engines, cfg.net.drop_prob, cfg.net.dup_prob
    );

    let make = |_w: usize, _e: usize| -> Box<dyn Compute> { Box::new(NativeCompute) };
    let report = mp::train_mp(&cfg, &ds, &make);

    for (e, l) in report.loss_per_epoch.iter().enumerate() {
        println!("epoch {e:>2}: loss/sample {:.5}", l / ds.n as f32);
    }
    println!(
        "\nprotocol: {} PA packets, {} retransmissions, {} dup FAs absorbed",
        report.agg.pa_sent, report.agg.retransmits, report.agg.dup_fa
    );
    println!(
        "pipeline: {} micro-batches overlapped with later forwards, {} drained at the tail",
        report.pipeline.overlapped, report.pipeline.drained
    );
    println!("wall: {:?}", report.wall);
    assert!(
        report.loss_per_epoch.last().unwrap() < &(0.7 * report.loss_per_epoch[0]),
        "training must converge despite the lossy fabric"
    );
    println!("converged under packet loss — exactly-once aggregation held");
}
